"""Load-balancing ablation (paper §II.D static schedule vs §IV.C's proposed
dynamic balancing): makespan of static / cost-weighted / LPT schedules under
content-dependent per-region costs (the paper's P5 meanshift variance case).

derived = makespan ratio vs static (lower is better).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import ImageInfo, StripeSplitter, whole
from repro.core.scheduling import (
    cost_weighted_static_schedule,
    lpt_schedule,
    makespan,
    static_schedule,
)


def run(rows: int = 4096, cols: int = 1024, n_workers: int = 16) -> List:
    info = ImageInfo(rows, cols, 4, np.float32)
    regions = StripeSplitter(n_splits=n_workers * 8).split(whole(rows, cols), info)
    rng = np.random.default_rng(0)
    # content-dependent cost: lognormal per region (meanshift-like variance)
    costs = rng.lognormal(mean=0.0, sigma=1.0, size=len(regions))
    cost_fn = lambda r: float(costs[r.row0 // (rows // len(regions))])

    out = []
    t0 = time.perf_counter()
    ms_static = makespan(static_schedule(regions, n_workers), regions, cost_fn)
    t_static = time.perf_counter() - t0
    out.append(("balance_static", t_static * 1e6, 1.0))

    t0 = time.perf_counter()
    ms_cw = makespan(
        cost_weighted_static_schedule(regions, n_workers, cost_fn), regions, cost_fn
    )
    out.append(("balance_cost_weighted", (time.perf_counter() - t0) * 1e6,
                ms_cw / ms_static))

    t0 = time.perf_counter()
    ms_lpt = makespan(lpt_schedule(regions, n_workers, cost_fn), regions, cost_fn)
    out.append(("balance_lpt", (time.perf_counter() - t0) * 1e6,
                ms_lpt / ms_static))
    return out
