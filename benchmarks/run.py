"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only io,pipelines,...]

Prints ``name,us_per_call,derived`` CSV rows (derived: speedup for I/O,
partition efficiency for pipelines, makespan ratio for balancing,
Mpixel/s-Mtoken/s for kernels, roofline fraction for the dry-run cells).

A benchmark that raises makes the harness exit non-zero (the CI smoke job
depends on this — a silently-skipped bench reads as "passed").  The only
tolerated skip is the roofline section, which needs dry-run artifacts that a
fresh checkout has not generated yet; its skip is announced on stderr.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

#: section name -> (module path, callable taking the parsed args)
SECTIONS = {
    "io": ("benchmarks.bench_io", lambda mod, args: mod.run()),
    "streaming": (
        "benchmarks.bench_streaming",
        lambda mod, args: mod.run(quick=args.quick),
    ),
    "pipelines": ("benchmarks.bench_pipelines", lambda mod, args: mod.run()),
    "balancing": ("benchmarks.bench_balancing", lambda mod, args: mod.run()),
    "kernels": ("benchmarks.bench_kernels", lambda mod, args: mod.run()),
    "roofline": ("benchmarks.bench_roofline", lambda mod, args: mod.run()),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(SECTIONS))
    ap.add_argument(
        "--quick", action="store_true",
        help="fast smoke path (CI): benches that support it skip slow sweeps",
    )
    args = ap.parse_args(argv)
    wanted = [w for w in args.only.split(",") if w]
    unknown = [w for w in wanted if w not in SECTIONS]
    if unknown:
        print(
            f"unknown benchmark section(s) {unknown}; "
            f"known: {sorted(SECTIONS)}",
            file=sys.stderr,
        )
        return 2

    rows = []
    failures = []
    for name in wanted:
        module_path, invoke = SECTIONS[name]
        try:
            mod = importlib.import_module(module_path)
            rows += invoke(mod, args)
        except Exception as e:
            if name == "roofline":
                # dry-run artifacts may not have been generated yet
                print(f"# roofline skipped: {e}", file=sys.stderr)
                continue
            traceback.print_exc()
            failures.append((name, e))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")
    if failures:
        for name, e in failures:
            print(f"# FAILED {name}: {e!r}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
