"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only io,pipelines,...]
                                            [--snapshot BENCH.json]

Prints ``name,us_per_call,derived`` CSV rows (derived: speedup for I/O,
partition efficiency for pipelines, makespan ratio for balancing,
pipelined/barrier wall ratio for the orchestrator, Mpixel/s-Mtoken/s for
kernels, roofline fraction for the dry-run cells).
Section order follows ``--only``, so consumers must key on row *names*, not
on row positions.

``--snapshot PATH`` additionally writes a machine-readable JSON perf
snapshot (every row, plus the headline plan-layer metrics: describe-pass
hit cost, lower/describe cost ratio, streaming speedups, compile counts) —
CI uploads one per run so the perf trajectory accumulates comparable
points across PRs.

A benchmark that raises makes the harness exit non-zero (the CI smoke job
depends on this — a silently-skipped bench reads as "passed"), but the rows
it measured before failing are still printed and snapshotted when the
exception carries them as ``partial_rows`` — a late gate failure must not
discard the section's data points.  An unknown
``--only`` section name exits non-zero listing the valid names (with a
did-you-mean hint for near-misses).  The only tolerated skip is the
roofline section, which needs dry-run artifacts that a fresh checkout has
not generated yet; its skip is announced on stderr.
"""
from __future__ import annotations

import argparse
import difflib
import importlib
import json
import sys
import traceback

#: section name -> (module path, callable taking the parsed args)
SECTIONS = {
    "io": ("benchmarks.bench_io", lambda mod, args: mod.run(quick=args.quick)),
    "streaming": (
        "benchmarks.bench_streaming",
        lambda mod, args: mod.run(quick=args.quick),
    ),
    "pipelines": ("benchmarks.bench_pipelines", lambda mod, args: mod.run()),
    "balancing": ("benchmarks.bench_balancing", lambda mod, args: mod.run()),
    "orchestrator": (
        "benchmarks.bench_orchestrator",
        lambda mod, args: mod.run(quick=args.quick),
    ),
    "kernels": ("benchmarks.bench_kernels", lambda mod, args: mod.run()),
    "serving": (
        "benchmarks.bench_serving",
        lambda mod, args: mod.run(quick=args.quick),
    ),
    "roofline": ("benchmarks.bench_roofline", lambda mod, args: mod.run()),
}

#: snapshot headline metrics: key -> (csv row name, which csv column)
_SNAPSHOT_METRICS = {
    "plan_describe_hit_cost_us": ("plan_describe_pass_us", "us_per_call"),
    "plan_lower_over_describe": ("plan_describe_pass_us", "derived"),
    "streaming_speedup_vs_rejit": ("streaming_P2_engine_cached", "derived"),
    "streaming_async_speedup_vs_rejit": ("streaming_P2_engine_async", "derived"),
    "streaming_compile_count": ("streaming_P2_compiles", "us_per_call"),
    # PR 9 tile-grid column: 2-D tiles vs 1-D strips on a wide image, and the
    # one-compile proof that every tile shares the interior signature
    "streaming_grid_tiles_over_strips": ("streaming_grid_tiles_2d", "derived"),
    "streaming_grid_tile_compiles": ("streaming_grid_tile_compiles", "us_per_call"),
    "orchestrator_pipelined_over_barrier": ("orch_chain_pipelined", "derived"),
    "orchestrator_max_in_flight": ("orch_chain_max_in_flight", "us_per_call"),
    # PR 7 pallas fast path: fused-chain Mpixels/s, pallas-vs-jnp speedup and
    # the TPU-projected roofline fraction for the heaviest kernel
    "kernel_fused_chain_mpix_s": ("kernel_fused_chain_pallas_256", "derived"),
    "kernel_fused_over_jnp": ("kernel_fused_speedup", "derived"),
    "kernel_meanshift_roofline_fraction": ("kernel_meanshift_roofline", "derived"),
    # PR 8 plan-warm tile serving: batched-storm p99 latency + throughput,
    # engine speedup over per-tile pulls, and the zero-lowers warm-up proof
    "serving_p99_batched_us": ("serving_storm_batched_p99", "us_per_call"),
    "serving_tiles_per_sec": ("serving_storm_batched", "derived"),
    "serving_batched_speedup": ("serving_batched_speedup", "derived"),
    "serving_post_warm_lowers": ("serving_first_request_lowers", "derived"),
    # PR 10 cloud-native IO: flat/tiled time ratio for scattered windowed
    # reads (> 1 when the RTIC tile layout beats flat row-segment reads)
    "io_tiled_over_flat": ("io_read_tiled_win", "derived"),
}


def write_snapshot(path: str, rows, sections) -> None:
    """Write the JSON perf snapshot: every CSV row keyed by name, plus the
    headline plan-layer metrics when their rows ran in this invocation."""
    by_name = {
        name: {"us_per_call": us, "derived": derived}
        for name, us, derived in rows
    }
    metrics = {
        key: by_name[row][col]
        for key, (row, col) in _SNAPSHOT_METRICS.items()
        if row in by_name
    }
    with open(path, "w") as f:
        json.dump(
            {"sections": list(sections), "metrics": metrics, "rows": by_name},
            f,
            indent=2,
            sort_keys=True,
        )
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(SECTIONS))
    ap.add_argument(
        "--quick", action="store_true",
        help="fast smoke path (CI): benches that support it skip slow sweeps",
    )
    ap.add_argument(
        "--snapshot", default=None, metavar="PATH",
        help="also write a JSON perf snapshot (rows + headline metrics)",
    )
    args = ap.parse_args(argv)
    wanted = [w for w in args.only.split(",") if w]
    unknown = [w for w in wanted if w not in SECTIONS]
    if unknown:
        hints = []
        for w in unknown:
            close = difflib.get_close_matches(w, SECTIONS, n=1)
            if close:
                hints.append(f"{w!r} (did you mean {close[0]!r}?)")
            else:
                hints.append(repr(w))
        print(
            f"unknown benchmark section(s) {', '.join(hints)}; "
            f"valid sections: {', '.join(sorted(SECTIONS))}",
            file=sys.stderr,
        )
        return 2

    rows = []
    failures = []
    for name in wanted:
        module_path, invoke = SECTIONS[name]
        try:
            mod = importlib.import_module(module_path)
            rows += invoke(mod, args)
        except Exception as e:
            if name == "roofline":
                # dry-run artifacts may not have been generated yet
                print(f"# roofline skipped: {e}", file=sys.stderr)
                continue
            traceback.print_exc()
            # a gated bench that fails late attaches everything it measured
            # before the gate as ``partial_rows`` — harvest them so the CSV
            # and the JSON snapshot still carry the section's data points
            rows += list(getattr(e, "partial_rows", ()) or ())
            failures.append((name, e))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")
    if args.snapshot:
        write_snapshot(args.snapshot, rows, wanted)
    if failures:
        for name, e in failures:
            print(f"# FAILED {name}: {e!r}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
