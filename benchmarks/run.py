"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only io,pipelines,...]

Prints ``name,us_per_call,derived`` CSV rows (derived: speedup for I/O,
partition efficiency for pipelines, makespan ratio for balancing,
Mpixel/s-Mtoken/s for kernels, roofline fraction for the dry-run cells).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="io,streaming,pipelines,balancing,kernels,roofline")
    ap.add_argument(
        "--quick", action="store_true",
        help="fast smoke path (CI): benches that support it skip slow sweeps",
    )
    args = ap.parse_args()
    wanted = set(args.only.split(","))

    rows = []
    if "io" in wanted:
        from benchmarks import bench_io

        rows += bench_io.run()
    if "streaming" in wanted:
        from benchmarks import bench_streaming

        rows += bench_streaming.run(quick=args.quick)
    if "pipelines" in wanted:
        from benchmarks import bench_pipelines

        rows += bench_pipelines.run()
    if "balancing" in wanted:
        from benchmarks import bench_balancing

        rows += bench_balancing.run()
    if "kernels" in wanted:
        from benchmarks import bench_kernels

        rows += bench_kernels.run()
    if "roofline" in wanted:
        from benchmarks import bench_roofline

        try:
            rows += bench_roofline.run()
        except Exception as e:  # dry-run results not generated yet
            print(f"# roofline skipped: {e}", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()
