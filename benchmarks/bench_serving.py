"""Tile-storm serving benchmark: the plan-warm batched engine under load.

Two servers over the same registered pipelines, both **plan-warm** (every
tile signature lowered + compiled before the storm — compile cost is PR 2's
story, not this one):

  * unbatched — ``max_batch=1``, no read cache: every request is an
    independent per-tile streaming pull through the registry, the obvious
    way to put the ExecutionPlan layer behind a tile endpoint;
  * batched — the engine this PR adds: requests coalesce by plan signature
    into vmap-batched invocations, and a bounded read LRU absorbs the
    per-tile source reads that batching cannot.

The storm is closed-loop: 16 client threads each submit-and-wait through
``TileServer.submit`` over a Zipf-popularity tile mix (a map-traffic shape:
a few hot tiles, a long cold tail) across the registered pipelines and
zooms.  Reported per mode: p50/p99 request latency and tiles/sec.

Gated claims (``REPRO_BENCH_NO_GATE=1`` downgrades to warnings; a gate
failure still hands the harness every row measured so far via the
exception's ``partial_rows``):

  * the first post-warm request performs **zero** new lowers and zero new
    XLA compiles — warm() really does leave only registry hits;
  * batched p99 latency beats unbatched p99;
  * batched tiles/sec ≥ 2× unbatched at concurrency 16.
"""
from __future__ import annotations

import os
import sys
import threading
import time
from typing import List, Tuple

import numpy as np

from repro import pipelines as PP
from repro.core import PlanCache
from repro.serve import Shed, TileRequest

CONCURRENCY = 16


def _gate(ok: bool, msg: str, rows: List) -> None:
    """Benchmark gate: raise (carrying the rows measured so far) unless the
    opt-out env is set."""
    if ok:
        return
    if os.environ.get("REPRO_BENCH_NO_GATE"):
        print(f"# WARNING (gate skipped): {msg}", file=sys.stderr)
        return
    err = AssertionError(msg)
    err.partial_rows = list(rows)
    raise err


def _build(batched: bool, quick: bool, plan_cache: PlanCache):
    kw = dict(
        rows_xs=64,
        cols_xs=64,
        zooms=(0,) if quick else (0, 1),
        pipelines=("P2",) if quick else ("P2", "P3", "P5"),
        tile_rows=16,
        plan_cache=plan_cache,
        tile_cache_entries=0,  # measure the compute path, not dict lookups
        prefetch_neighbors=False,
        use_pallas=False,
    )
    if batched:
        kw.update(max_batch=CONCURRENCY, batch_sizes=(1, 4, CONCURRENCY))
    else:
        kw.update(max_batch=1, batch_sizes=(1,), read_cache_entries=0)
    return PP.build_tile_server(**kw)


def _zipf_requests(server, n: int, seed: int = 0) -> List[TileRequest]:
    """A Zipf-popularity request mix over every registered tile: rank the
    (pipeline, zoom, x, y) universe in a seeded shuffle, weight rank r by
    1/r^1.1, sample ``n`` requests."""
    universe = [
        TileRequest(name, z, x, y)
        for name, z in server.entries()
        for x, y in server._entries[(name, z)].grid.tiles()
    ]
    rng = np.random.default_rng(seed)
    rng.shuffle(universe)
    weights = 1.0 / np.arange(1, len(universe) + 1) ** 1.1
    weights /= weights.sum()
    picks = rng.choice(len(universe), size=n, p=weights)
    return [universe[i] for i in picks]


def _storm(server, requests: List[TileRequest]) -> Tuple[List[float], float, int]:
    """Closed-loop storm: CONCURRENCY client threads submit-and-wait their
    share of ``requests``.  Returns (latencies_s, wall_s, shed_count)."""
    latencies: List[float] = []
    shed = [0]
    lock = threading.Lock()

    def client(chunk: List[TileRequest]) -> None:
        lats = []
        for req in chunk:
            t0 = time.perf_counter()
            try:
                server.submit(req).result(timeout=300)
            except Shed:
                with lock:
                    shed[0] += 1
                continue
            lats.append(time.perf_counter() - t0)
        with lock:
            latencies.extend(lats)

    threads = [
        threading.Thread(target=client, args=(requests[i::CONCURRENCY],))
        for i in range(CONCURRENCY)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return latencies, wall, shed[0]


def _run_mode(batched: bool, quick: bool, requests, rows: List, label: str):
    cache = PlanCache(max_entries=4096)
    server = _build(batched, quick, cache)
    t0 = time.perf_counter()
    warm = server.warm()
    dt_warm = time.perf_counter() - t0
    n_sigs = sum(w["signatures"] for w in warm.values())
    if batched:
        rows.append(("serving_warm_us", dt_warm * 1e6, float(n_sigs)))

        # the headline warm-up claim: the first live request after warm() is
        # a pure registry hit — zero new lowers, zero new XLA compiles
        before = cache.stats_snapshot()
        t0 = time.perf_counter()
        server.serve_one(requests[0])
        dt_first = time.perf_counter() - t0
        after = cache.stats_snapshot()
        delta = (after["lowers"] - before["lowers"]) + (
            after["compiles"] - before["compiles"]
        )
        rows.append(("serving_first_request_lowers", dt_first * 1e6, float(delta)))
        _gate(
            delta == 0,
            f"first post-warm request lowered/compiled (delta={delta})",
            rows,
        )

    with server:
        lats, wall, shed = _storm(server, requests)
    if shed:
        print(f"# serving[{label}]: {shed} requests shed", file=sys.stderr)
    lats_us = np.asarray(sorted(lats)) * 1e6
    p50 = float(np.percentile(lats_us, 50))
    p99 = float(np.percentile(lats_us, 99))
    tps = len(lats) / wall
    rows.append((f"serving_storm_{label}", p50, tps))
    rows.append((f"serving_storm_{label}_p99", p99, tps))
    if batched:
        hist = server.metrics()["batch_histogram"]
        total = sum(hist.values())
        mean_batch = sum(k * v for k, v in hist.items()) / max(1, total)
        rows.append(("serving_batch_mean", mean_batch, float(max(hist or {0: 0}))))
    return p99, tps


def run(quick: bool = False) -> List:
    rows: List = []
    n = 320 if quick else 1600
    # the request mix is drawn once against the batched server's registry;
    # both servers register identical entries, so it replays on either
    probe = _build(True, quick, PlanCache())
    requests = _zipf_requests(probe, n)

    u_p99, u_tps = _run_mode(False, quick, requests, rows, "unbatched")
    b_p99, b_tps = _run_mode(True, quick, requests, rows, "batched")

    rows.append(("serving_batched_speedup", b_p99, b_tps / u_tps))
    _gate(
        b_p99 < u_p99,
        f"batched p99 {b_p99:.0f}us not below unbatched p99 {u_p99:.0f}us",
        rows,
    )
    _gate(
        b_tps >= 2.0 * u_tps,
        f"batched {b_tps:.0f} tiles/s < 2x unbatched {u_tps:.0f} tiles/s "
        f"at concurrency {CONCURRENCY}",
        rows,
    )
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick="--quick" in sys.argv):
        print(f"{name},{us:.1f},{derived:.4f}")
