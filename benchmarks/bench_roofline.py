"""Roofline table: three terms per (arch × shape × mesh) from the dry-run
JSONs (experiments/dryrun/).  Single-pod only per the spec; multi-pod cells
are validated for compile success separately.

derived = roofline fraction (compute_s / dominant_s); us_per_call = the
step-time lower bound (max of the three terms) in µs.
"""
from __future__ import annotations

import glob
import json
import pathlib
from typing import List

from repro.launch.analysis import roofline_terms
from repro.launch.mesh import HW


def model_flops(rec: dict) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch tokens."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n * tokens
    return 2.0 * n * rec["global_batch"]  # decode: one token per sequence


def analyze_record(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    flops_dev = rec["cost_corrected"]["flops"]
    # streaming-implementation bytes when available (the naive-attention
    # analysis variant's bytes include S² score materialization)
    bytes_dev = rec["cost_corrected"].get(
        "bytes_accessed_streaming", rec["cost_corrected"]["bytes_accessed"]
    )
    coll_dev = rec["collectives_corrected"]["total"]
    terms = roofline_terms(flops_dev, bytes_dev, coll_dev, HW)
    mf = model_flops(rec)
    hlo_total = flops_dev * n_dev
    return {
        **terms,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_fraction": mf / hlo_total if hlo_total else 0.0,
        "hbm_used_frac": rec.get("hbm_used_frac"),
        "fits_hbm": rec.get("fits_hbm"),
    }


def run(dryrun_dir: str = "experiments/dryrun") -> List:
    out = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*__single.json")):
        rec = json.loads(pathlib.Path(f).read_text())
        if rec.get("skipped") or "error" in rec:
            continue
        a = analyze_record(rec)
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        out.append((name, a["step_time_lower_bound_s"] * 1e6,
                    round(a["roofline_fraction"], 4)))
    return out


def full_table(dryrun_dir: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*__single.json")):
        rec = json.loads(pathlib.Path(f).read_text())
        if rec.get("skipped") or "error" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": rec.get("reason", rec.get("error", ""))})
            continue
        a = analyze_record(rec)
        rows.append({"arch": rec["arch"], "shape": rec["shape"], **a})
    return rows
