"""Kernel microbenchmarks: jnp reference path wall time on CPU (the Pallas
kernels target TPU; interpret mode is a correctness harness, not a timing
one).  derived = Mpixels/s (geospatial) or Mtokens/s-equivalents (LM).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, repeats=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> List:
    rng = np.random.default_rng(0)
    out = []

    H = W = 256
    halo = 3
    band = jnp.asarray(rng.uniform(0, 4096, (H + 2 * halo, W + 2 * halo)).astype(np.float32))
    f = jax.jit(lambda b: ref.glcm_features_ref(b, 2, (0, 1), 8, 0.0, 4096.0))
    t = _time(f, band)
    out.append(("kernel_glcm_ref_256", t * 1e6, H * W / t / 1e6))

    xs = jnp.asarray(rng.uniform(0, 4096, (H, W, 4)).astype(np.float32))
    pan = jnp.asarray(rng.uniform(1, 4096, (H + 4, W + 4, 1)).astype(np.float32))
    f = jax.jit(lambda a, b: ref.pansharpen_ref(a, b, 2))
    t = _time(f, xs, pan)
    out.append(("kernel_pansharpen_ref_256", t * 1e6, H * W / t / 1e6))

    x = jnp.asarray(rng.uniform(0, 500, (H + 4, W + 4, 4)).astype(np.float32))
    f = jax.jit(lambda a: ref.meanshift_ref(a, 2, 120.0, 2))
    t = _time(f, x)
    out.append(("kernel_meanshift_ref_256", t * 1e6, H * W / t / 1e6))

    BH, S, D = 8, 512, 64
    q = jnp.asarray(rng.normal(size=(BH, S, D)).astype(np.float32))
    f = jax.jit(lambda a: ref.attention_ref(a, a, a, True))
    t = _time(f, q)
    out.append(("kernel_attention_ref_512", t * 1e6, BH * S / t / 1e6))

    BHC, L, P, N = 32, 64, 32, 16
    xs_ = jnp.asarray(rng.normal(size=(BHC, L, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (BHC, L)).astype(np.float32))
    cum = jnp.cumsum(-dt, axis=1)
    B = jnp.asarray(rng.normal(size=(BHC, L, N)).astype(np.float32))
    f = jax.jit(lambda x_, d, c, b: ref.ssd_intra_ref(x_, d, c, b, b)[0])
    t = _time(f, xs_, dt, cum, B)
    out.append(("kernel_ssd_ref_64", t * 1e6, BHC * L / t / 1e6))
    return out
