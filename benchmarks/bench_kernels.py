"""Kernel microbenchmarks: jnp reference path vs the Pallas kernels (interpret
mode on CPU — the tiled grid still jits to XLA, so wall times are real and the
tiling's cache locality beats the window-stacking jnp references).  derived =
Mpixels/s (geospatial) or Mtokens/s-equivalents (LM).

``kernel_*_pallas_*`` rows carry the plan-layer fast-path numbers, the
``kernel_fused_chain_256`` pair measures a Convert+BandMath chain folded into
the mean-shift kernel versus the same chain as staged jnp passes, and
``kernel_*_roofline`` rows project each kernel's analytic (flops, bytes)
through :func:`repro.launch.analysis.roofline_terms` under the same HW model
as ``bench_roofline`` (us = the TPU step-time lower bound; derived = measured
CPU throughput as a fraction of that bound's throughput — a projection,
honestly ≪ 1 on CPU).

The throughput gate: :func:`run` asserts the Pallas rows do not regress below
the jnp reference rows (the PR-7 acceptance bar — fused throughput ≥ plain
jnp).  Set ``REPRO_BENCH_NO_GATE=1`` to record without gating.
"""
from __future__ import annotations

import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import glcm as glcm_k
from repro.kernels import meanshift as ms_k
from repro.kernels import pansharpen as ps_k
from repro.kernels import ref
from repro.launch.analysis import roofline_terms
from repro.launch.mesh import HW


def _time(fn, *args, repeats=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _gate(name: str, t_jnp: float, t_pallas: float, rows=()) -> None:
    """Pallas row must meet the jnp row's throughput (5% timing jitter).  On
    failure the raised error carries ``rows`` as ``partial_rows`` so the
    harness still records everything measured before the gate."""
    if os.environ.get("REPRO_BENCH_NO_GATE"):
        return
    if t_pallas > t_jnp * 1.05:
        err = AssertionError(
            f"{name}: pallas {t_pallas * 1e3:.1f}ms slower than jnp "
            f"{t_jnp * 1e3:.1f}ms — fused fast path regressed"
        )
        err.partial_rows = list(rows)
        raise err


def _roofline_row(name: str, flops: float, bytes_: float, measured_s: float,
                  pixels: float):
    """Project the kernel's analytic cost through the bench_roofline HW model:
    us = TPU step-time lower bound, derived = measured/bound throughput."""
    terms = roofline_terms(flops, bytes_, 0.0, HW)
    bound = terms["step_time_lower_bound_s"]
    return (name, bound * 1e6,
            round(bound / measured_s, 6) if measured_s else 0.0)


def run() -> List:
    rng = np.random.default_rng(0)
    out = []

    H = W = 256
    halo = 3
    band = jnp.asarray(rng.uniform(0, 4096, (H + 2 * halo, W + 2 * halo)).astype(np.float32))
    f = jax.jit(lambda b: ref.glcm_features_ref(b, 2, (0, 1), 8, 0.0, 4096.0))
    t = _time(f, band)
    out.append(("kernel_glcm_ref_256", t * 1e6, H * W / t / 1e6))
    f = jax.jit(lambda b: glcm_k.glcm_features(b, 2, (0, 1), 8, 0.0, 4096.0))
    tp = _time(f, band)
    out.append(("kernel_glcm_pallas_256", tp * 1e6, H * W / tp / 1e6))
    _gate("glcm", t, tp, out)
    # per pixel: 25-px window × 8² joint histogram scatter + 5 feature sums
    out.append(_roofline_row(
        "kernel_glcm_roofline", H * W * (25 * 64 * 2 + 5 * 64 * 2),
        (band.size + H * W * 5) * 4, tp, H * W))

    xs = jnp.asarray(rng.uniform(0, 4096, (H, W, 4)).astype(np.float32))
    pan = jnp.asarray(rng.uniform(1, 4096, (H + 4, W + 4, 1)).astype(np.float32))
    f = jax.jit(lambda a, b: ref.pansharpen_ref(a, b, 2))
    t = _time(f, xs, pan)
    out.append(("kernel_pansharpen_ref_256", t * 1e6, H * W / t / 1e6))
    f = jax.jit(lambda a, b: ps_k.pansharpen(a, b, 2))
    tp = _time(f, xs, pan)
    out.append(("kernel_pansharpen_pallas_256", tp * 1e6, H * W / tp / 1e6))
    _gate("pansharpen", t, tp, out)
    # per pixel: 25-px box sum + ratio + 4-band multiply
    out.append(_roofline_row(
        "kernel_pansharpen_roofline", H * W * (25 + 2 + 4),
        (xs.size + pan.size + H * W * 4) * 4, tp, H * W))

    x = jnp.asarray(rng.uniform(0, 500, (H + 4, W + 4, 4)).astype(np.float32))
    f = jax.jit(lambda a: ref.meanshift_ref(a, 2, 120.0, 2))
    t = _time(f, x)
    out.append(("kernel_meanshift_ref_256", t * 1e6, H * W / t / 1e6))
    f = jax.jit(lambda a: ms_k.meanshift(a, 2, 120.0, 2))
    tp = _time(f, x)
    out.append(("kernel_meanshift_pallas_256", tp * 1e6, H * W / tp / 1e6))
    _gate("meanshift", t, tp, out)
    # per pixel per iter: 25-window × 4-band distance + masked mean (~3 ops/el)
    out.append(_roofline_row(
        "kernel_meanshift_roofline", H * W * 2 * (25 * 4 * 3),
        (x.size * 2) * 4, tp, H * W))

    # fused chain: Convert+BandMath folded into the mean-shift kernel's
    # pre_fn (ONE pallas call) vs the same chain as staged jnp passes —
    # the tentpole's fused-vs-jnp wall-time comparison
    def pre(t_):
        return ((t_.astype(jnp.float32) - 0.0) / 4096.0 * 255.0) * 0.5 + 1.0

    f = jax.jit(lambda a: ref.meanshift_ref(pre(a), 2, 120.0, 2))
    t = _time(f, x)
    out.append(("kernel_fused_chain_jnp_256", t * 1e6, H * W / t / 1e6))
    f = jax.jit(lambda a: ms_k.meanshift(a, 2, 120.0, 2, pre_fn=pre))
    tp = _time(f, x)
    out.append(("kernel_fused_chain_pallas_256", tp * 1e6, H * W / tp / 1e6))
    out.append(("kernel_fused_speedup", tp * 1e6, t / tp))
    _gate("fused_chain", t, tp, out)

    BH, S, D = 8, 512, 64
    q = jnp.asarray(rng.normal(size=(BH, S, D)).astype(np.float32))
    f = jax.jit(lambda a: ref.attention_ref(a, a, a, True))
    t = _time(f, q)
    out.append(("kernel_attention_ref_512", t * 1e6, BH * S / t / 1e6))

    BHC, L, P, N = 32, 64, 32, 16
    xs_ = jnp.asarray(rng.normal(size=(BHC, L, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (BHC, L)).astype(np.float32))
    cum = jnp.cumsum(-dt, axis=1)
    B = jnp.asarray(rng.normal(size=(BHC, L, N)).astype(np.float32))
    f = jax.jit(lambda x_, d, c, b: ref.ssd_intra_ref(x_, d, c, b, b)[0])
    t = _time(f, xs_, dt, cum, B)
    out.append(("kernel_ssd_ref_64", t * 1e6, BHC * L / t / 1e6))
    return out
