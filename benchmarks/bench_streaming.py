"""Streaming-engine benchmark: plan-cache + double buffering vs seed behavior.

Three measurements on a uniform-stripe P2 (Haralick textures) run:

  * rejit_baseline — the seed semantics (``cache=False``): ``jax.jit`` of a
    fresh closure every region, so every stripe retraces and recompiles;
  * engine_cached  — the PlanCache path (one compile per signature), still
    synchronous (``prefetch=0``);
  * engine_async   — cached + double-buffered (``prefetch=2``), writing
    through the RTIF write-behind stage.

Reported ``derived`` columns: regions/sec for the baseline row, speedup vs
the baseline for the engine rows, compile count for the compile row (must be
3 on striped P2: top/interior/bottom boundary signatures, of which only the
interior one is hit repeatedly), and sequential/pool wall-time ratio for the
work-stealing orchestrator row.

The ``plan_describe_vs_lower`` rows microbench the ExecutionPlan layer's
cache-hit cost: a registry hit runs the describe pass only, so its per-region
host overhead must beat the old hit path (describe **plus** rebuilding the
O(graph) closure tree).  ``run(quick=True)`` (CI smoke: ``--quick``) keeps
the cached-engine measurement and this microbench, and skips the slow
baseline/I/O/pool sweeps.
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path
from typing import List

import numpy as np

from repro import pipelines as PP
from repro.core import PlanCache, StreamingExecutor, StripeSplitter, run_pool
from repro.raster import ParallelRasterWriter, SyntheticScene

ROWS, COLS, STRIPES = 192, 64, 12


def _p2(tmp: Path, tag: str):
    src = SyntheticScene(ROWS, COLS, bands=4, dtype=np.float32)
    return PP.p2_textures(
        src, mapper_factory=lambda: ParallelRasterWriter(str(tmp / f"{tag}.rtif"))
    )


def _timed(executor: StreamingExecutor):
    t0 = time.perf_counter()
    res = executor.run()
    return time.perf_counter() - t0, res


def _plan_layer_microbench(out: List, quick: bool) -> None:
    """Cache-hit host cost: describe pass alone vs describe + closure build
    (what every registry hit used to pay before the describe/lower split).

    Uses a deep filter chain on a fine split — the regime the refactor
    targets (per-region host overhead scales with graph size) — and takes the
    best of several trials so scheduler noise doesn't drown the ratio."""
    from repro.filters import gaussian_smoothing
    from repro.raster import MemoryMapper

    from repro.core import Pipeline

    p = Pipeline()
    n = p.add(SyntheticScene(256, 64, bands=2, dtype=np.float32))
    for _ in range(12):
        n = p.add(gaussian_smoothing(1.0), [n])
    m = p.add(MemoryMapper(), [n])
    info = p.info(m)
    regions = StripeSplitter(n_splits=16).split(info.full_region, info)
    for r in regions:  # warm both walks
        p.describe_pull(m, r)
        p.compile_pull(m, r)

    reps, trials = (3, 3) if quick else (20, 5)

    def best(fn):
        times = []
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(reps):
                for r in regions:
                    fn(m, r)
            times.append((time.perf_counter() - t0) / (reps * len(regions)))
        return min(times)

    dt_describe = best(p.describe_pull)
    dt_lower = best(p.compile_pull)

    out.append(("plan_describe_pass_us", dt_describe * 1e6, dt_lower / dt_describe))
    out.append(("plan_describe_plus_lower_us", dt_lower * 1e6, dt_lower / dt_describe))
    if dt_describe >= dt_lower:
        print("# WARNING: describe pass not cheaper than describe+lower "
              f"({dt_describe*1e6:.1f}us vs {dt_lower*1e6:.1f}us)", file=sys.stderr)


def _tile_grid_bench(out: List, tmp: Path, quick: bool) -> None:
    """1-D strips vs the 2-D tile grid on a WIDE image (PR 9).

    An nr·nc-way strip split of a wide image yields long skinny stripes
    whose halo rows span the full width; the matching nr×nc tile grid
    (``padded_tile_grid``) keeps regions square-ish, so the halo perimeter
    per pixel shrinks.  Streaming over the Hr×Wc tile geometry is the
    single-process analogue of the 2-D SPMD mesh — and must still be ONE
    compile: grid-mode virtual describes give every tile (ragged columns
    included) the shared interior signature.  Derived columns: regions/sec
    for the strip row, strip/tile wall ratio for the tile row, registry
    hits for the compile row."""
    from repro.core import TileSplitter, padded_tile_grid

    grows, gcols = (64, 512) if quick else (96, 768)
    gnr, gnc = 2, 4

    def build(tag):
        src = SyntheticScene(grows, gcols, bands=4, dtype=np.float32)
        return PP.p2_textures(
            src,
            mapper_factory=lambda: ParallelRasterWriter(str(tmp / f"{tag}.rtif")),
        )

    p, m = build("grid_strips")
    dt_strips, res = _timed(
        StreamingExecutor(p, m, StripeSplitter(n_splits=gnr * gnc),
                          plan_cache=PlanCache(), prefetch=0)
    )
    out.append(("streaming_grid_strips_1d", dt_strips * 1e6,
                res.regions_processed / dt_strips))

    Hr, Wc, _, _ = padded_tile_grid(grows, gcols, gnr, gnc)
    p, m = build("grid_tiles")
    cache = PlanCache()
    dt_tiles, _ = _timed(
        StreamingExecutor(p, m, TileSplitter(Hr, Wc), plan_cache=cache,
                          prefetch=0)
    )
    out.append(("streaming_grid_tiles_2d", dt_tiles * 1e6, dt_strips / dt_tiles))
    out.append(("streaming_grid_tile_compiles", float(cache.stats.compiles),
                float(cache.stats.hits)))
    if cache.stats.compiles != 1:
        print(f"# WARNING: expected 1 compile on the P2 tile grid, got "
              f"{cache.stats.compiles}", file=sys.stderr)


def run(quick: bool = False) -> List:
    out = []
    with tempfile.TemporaryDirectory(prefix="bench_streaming_") as d:
        tmp = Path(d)
        splitter = StripeSplitter(n_splits=STRIPES)

        _plan_layer_microbench(out, quick)

        dt_rejit = None
        if not quick:
            # seed semantics: retrace + recompile every region
            p, m = _p2(tmp, "rejit")
            dt_rejit, res = _timed(
                StreamingExecutor(p, m, splitter, cache=False, prefetch=0)
            )
            regions = res.regions_processed
            out.append(("streaming_P2_rejit_baseline", dt_rejit * 1e6,
                        regions / dt_rejit))

        # compiled-plan cache, synchronous loop
        p, m = _p2(tmp, "cached")
        cache = PlanCache()
        dt_cached, _ = _timed(
            StreamingExecutor(p, m, splitter, plan_cache=cache, prefetch=0)
        )
        out.append(("streaming_P2_engine_cached", dt_cached * 1e6,
                    (dt_rejit / dt_cached) if dt_rejit else 0.0))
        out.append(("streaming_P2_compiles", float(cache.stats.compiles),
                    float(cache.stats.hits)))
        if cache.stats.compiles != 1:  # virtual border describes: one signature
            print(f"# WARNING: expected 1 compile on striped P2, got "
                  f"{cache.stats.compiles}", file=sys.stderr)

        _tile_grid_bench(out, tmp, quick)
        if quick:
            return out

        # cached + async double buffering (measures read/write overlap)
        p, m = _p2(tmp, "async")
        dt_async, _ = _timed(
            StreamingExecutor(p, m, splitter, plan_cache=PlanCache(), prefetch=2)
        )
        out.append(("streaming_P2_engine_async", dt_async * 1e6, dt_rejit / dt_async))
        out.append(("streaming_P2_overlap", dt_async * 1e6, dt_cached / dt_async))

        # the bar: engine ≥ 5× regions/sec over per-region re-jit (warn, don't
        # abort the sweep — a loaded box can depress the ratio)
        if dt_rejit / min(dt_cached, dt_async) < 5.0:
            print(f"# WARNING: engine speedup below 5x "
                  f"(rejit {dt_rejit:.2f}s, cached {dt_cached:.2f}s, "
                  f"async {dt_async:.2f}s)", file=sys.stderr)

        # overlap on an I/O-bound pipeline (file → file copy): P2 above is
        # compute-bound, so double buffering shows its worth where the paper
        # says it matters — reads and writes hiding behind each other
        from repro.raster import RasterReader

        src_path = str(tmp / "io_src.rtif")
        p, m = PP.io_passthrough(
            SyntheticScene(2048, 512, bands=4, dtype=np.float32),
            mapper_factory=lambda: ParallelRasterWriter(src_path),
        )
        StreamingExecutor(p, m, StripeSplitter(n_splits=8)).run()
        io_splitter = StripeSplitter(n_splits=32)

        def _copy(tag, prefetch):
            p, m = PP.io_passthrough(
                RasterReader(src_path),
                mapper_factory=lambda: ParallelRasterWriter(str(tmp / f"{tag}.rtif")),
            )
            return _timed(
                StreamingExecutor(p, m, io_splitter, plan_cache=PlanCache(),
                                  prefetch=prefetch)
            )[0]

        dt_io_sync = _copy("io_sync", 0)
        dt_io_async = _copy("io_async", 4)
        out.append(("streaming_IO_overlap", dt_io_async * 1e6, dt_io_sync / dt_io_async))

        # orchestrator stage: sequential per-worker loop (seed) vs the
        # work-stealing thread pool on the same stage graph
        n_workers = 4
        stage_splitter = StripeSplitter(n_splits=n_workers * 4)

        p, m = _p2(tmp, "seq")
        t0 = time.perf_counter()
        for w in range(n_workers):  # the seed orchestrator's sequential loop
            StreamingExecutor(
                p, m, stage_splitter, worker=w, n_workers=n_workers, cache=False
            ).run()
        dt_seq = time.perf_counter() - t0

        p, m = _p2(tmp, "pool")
        t0 = time.perf_counter()
        run_pool(p, m, stage_splitter, n_workers=n_workers, scheduler="work_stealing")
        dt_pool = time.perf_counter() - t0
        # no hard assert: on a loaded 1–2 core box the thread pool can lose to
        # the sequential loop; the derived ratio reports the outcome either way
        out.append(("orchestrator_ws_pool_vs_seq", dt_pool * 1e6, dt_seq / dt_pool))
    return out
