"""Figure 1 reproduction: read/write scaling of the strip-parallel raster
writer vs number of workers (the paper's MPI ranks → writer threads here).

Prints ``name,us_per_call,derived`` CSV rows; derived = speedup vs 1 worker.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import ImageInfo, StripeSplitter, whole
from repro.raster import io as rio

WORKERS = (1, 2, 4, 8, 12, 16, 32)


def _time(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(rows: int = 2048, cols: int = 2048, bands: int = 4) -> list:
    """Scaled-down XS product (paper: 10699×11899×4 uint16)."""
    info = ImageInfo(rows, cols, bands, np.uint16)
    data = np.random.default_rng(0).integers(
        0, 4096, size=(rows, cols, bands)
    ).astype(np.uint16)
    tmp = Path(tempfile.mkdtemp())
    rows_out = []
    base_w = base_r = None
    for n in WORKERS:
        regions = StripeSplitter(n_splits=max(n, 8)).split(whole(rows, cols), info)
        strips = [(r, data[r.slices()]) for r in regions]
        path = str(tmp / f"io_{n}.rtif")

        t_w = _time(lambda: rio.parallel_write(path, info, strips, n_writers=n))
        t_r = _time(lambda: rio.parallel_read(path, regions, n_readers=n))
        base_w = base_w or t_w
        base_r = base_r or t_r
        rows_out.append((f"io_write_w{n}", t_w * 1e6, base_w / t_w))
        rows_out.append((f"io_read_w{n}", t_r * 1e6, base_r / t_r))
    return rows_out
