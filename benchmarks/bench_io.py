"""Figure 1 reproduction: read/write scaling of the strip-parallel raster
writer vs number of workers (the paper's MPI ranks → writer threads here),
plus the cloud-native column: windowed reads through the tiled RTIC
container vs the flat RTIF file.

Everything rides the Source/Sink protocol (``RasterReader.read_many`` /
``ParallelRasterWriter.write_many`` — the free-function trio is deprecated).

Prints ``name,us_per_call,derived`` CSV rows; derived = speedup vs 1 worker
for the scaling rows, flat/tiled time ratio for ``io_read_tiled_win`` (> 1
means the tile layout wins on small windows).
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import ImageInfo, ImageRegion, StripeSplitter, whole
from repro.raster import ParallelRasterWriter, RasterReader, TiledSource, TileWriter

WORKERS = (1, 2, 4, 8, 12, 16, 32)
WORKERS_QUICK = (1, 2, 4, 8)


def _time(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _write(path: str, info: ImageInfo, strips, n_writers: int) -> None:
    w = ParallelRasterWriter(path)
    w.begin(info)
    try:
        w.write_many(strips, n_writers=n_writers)
    finally:
        w.end()


def _windows(rows, cols, size=64, n=32, seed=7):
    rng = np.random.default_rng(seed)
    return [
        ImageRegion(
            (int(r), int(c)), (min(size, rows - r), min(size, cols - c))
        )
        for r, c in zip(
            rng.integers(0, max(1, rows - size), size=n),
            rng.integers(0, max(1, cols - size), size=n),
        )
    ]


def run(rows: int = 2048, cols: int = 2048, bands: int = 4,
        quick: bool = False) -> list:
    """Scaled-down XS product (paper: 10699×11899×4 uint16)."""
    if quick:
        rows, cols = min(rows, 1024), min(cols, 1024)
    info = ImageInfo(rows, cols, bands, np.uint16)
    data = np.random.default_rng(0).integers(
        0, 4096, size=(rows, cols, bands)
    ).astype(np.uint16)
    tmp = Path(tempfile.mkdtemp())
    rows_out = []
    base_w = base_r = None
    flat_path = None
    for n in WORKERS_QUICK if quick else WORKERS:
        regions = StripeSplitter(n_splits=max(n, 8)).split(whole(rows, cols), info)
        strips = [(r, data[r.slices()]) for r in regions]
        path = str(tmp / f"io_{n}.rtif")
        flat_path = flat_path or path

        t_w = _time(lambda: _write(path, info, strips, n_writers=n))
        reader = RasterReader(path)
        t_r = _time(lambda: reader.read_many(regions, n_readers=n))
        base_w = base_w or t_w
        base_r = base_r or t_r
        rows_out.append((f"io_write_w{n}", t_w * 1e6, base_w / t_w))
        rows_out.append((f"io_read_w{n}", t_r * 1e6, base_r / t_r))

    # -- tiled vs flat windowed reads (the cloud-serving access pattern) -----
    # small scattered windows: the flat file reads one byte range per window
    # row, the tiled container a handful of whole tiles (cached across
    # overlapping windows).  Report-only — the ratio depends on the page
    # cache — but the row keeps the comparison on the perf trajectory.
    tiled_path = str(tmp / "io.rtic")
    tw = TileWriter(tiled_path, tile_rows=256, levels=1)
    tw.begin(info)
    tw.consume(whole(rows, cols), data)
    tw.end()
    wins = _windows(rows, cols)
    flat = RasterReader(flat_path)
    t_flat = _time(lambda: flat.read_many(wins))
    tiled = TiledSource(tiled_path)
    try:
        t_tiled = _time(lambda: tiled.read_many(wins))
    finally:
        tiled.close()
    rows_out.append(("io_read_flat_win", t_flat * 1e6, 1.0))
    rows_out.append(("io_read_tiled_win", t_tiled * 1e6, t_flat / t_tiled))
    return rows_out
