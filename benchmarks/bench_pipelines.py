"""Table 2 reproduction: P1–P7 run time + speedup vs number of workers.

On this CPU host, virtual devices share the same cores, so *wall-clock*
speedup cannot reproduce the paper's cluster numbers.  What the paper's
table fundamentally measures is work partitioning with near-zero overhead;
we therefore report, per (pipeline × workers):

  * us_per_call — wall time of this worker-count's full run (host timing);
  * derived     — the partition efficiency: serial_pixels / (workers ×
                  max_pixels_per_worker), which is the paper's speedup/N
                  (1.0 = perfectly balanced static schedule, the paper
                  reaches 0.97–1.0 at N≤16; P3 drops to 0.72 at N=32).

The wall-clock speedup on a real pod is this efficiency times N, bounded by
the I/O fraction (paper §III.A) — benchmarked separately in bench_io.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro import pipelines as PP
from repro.core import StreamingExecutor, StripeSplitter
from repro.core.scheduling import makespan, static_schedule
from repro.raster import SyntheticScene, make_spot6_pair

WORKERS = (1, 2, 4, 8, 16, 32)


def _builders(rows=160, cols=128):
    src = lambda: SyntheticScene(rows, cols, bands=4, dtype=np.float32)
    return {
        "P1_ortho": lambda: PP.p1_orthorectification(src()),
        "P2_textures": lambda: PP.p2_textures(src()),
        "P3_pansharpen": lambda: PP.p3_pansharpening(*make_spot6_pair(rows // 4, cols // 4)),
        "P4_classify": lambda: PP.p4_classification(src()),
        "P5_meanshift": lambda: PP.p5_meanshift(src(), hs=2, n_iter=2),
        "P6_convert": lambda: PP.p6_conversion(src()),
        "P7_resample": lambda: PP.p7_resampling(SyntheticScene(rows // 4, cols // 4, bands=4, dtype=np.float32)),
    }


def run() -> List:
    out = []
    for name, build in _builders().items():
        for n in WORKERS:
            p, m = build()
            info = p.info(m)
            splitter = StripeSplitter(n_splits=max(n * 2, 8))
            regions = splitter.split(info.full_region, info)
            sched = static_schedule(regions, n)
            cost = lambda r: float(r.num_pixels)
            total = sum(cost(r) for r in regions)
            ms = makespan(sched, regions, cost)
            efficiency = total / (n * ms) if ms else 0.0

            t0 = time.perf_counter()
            # run worker 0's share (the makespan holder under static schedule)
            StreamingExecutor(p, m, splitter, worker=0, n_workers=n).run()
            dt = time.perf_counter() - t0
            out.append((f"{name}_w{n}", dt * 1e6, efficiency))
    return out
