"""Task-parallel vs data-parallel stage orchestration (ROADMAP item 1).

Reproduces the comparison the PAPERS.md workflow-design studies
(Paraskevakos, arXiv:1905.09766; Al-Saadi, arXiv:2010.14756) found decisive
for satellite-image workloads: a multi-stage job run **barrier-sequential**
(each stage fully materializes before the next starts — wall time is the
*sum* of stage times) vs **region-granularity pipelined** (all stages run
concurrently, consumers pull a region the moment its producer commits it —
wall time approaches the *slowest* stage plus a pipeline-fill ramp).

The measured chain is a 3-stage DAG with a fixed, host-side per-region cost
(`use_jit=False` + a sleeping identity filter), so the comparison is
deterministic on any CI runner: with S stages of T seconds each, barrier
wall is ~S*T while pipelined wall is ~T + (S-1)*T/n_regions.

Rows (derived column):
  orch_chain_barrier        wall time of the barrier oracle; derived = number of stages
  orch_chain_pipelined      pipelined wall time; derived = pipelined/barrier
                            ratio — the acceptance gate asserts < 0.75
  orch_chain_max_in_flight  peak strips in flight on any edge (us column);
                            derived = queue_capacity — the gate asserts
                            in-flight <= capacity (bounded intermediates)
  orch_chain_real_*         (full mode only) the real pansharpen → texture →
                            classify chain from `pipelines.chain_stages` on
                            the jitted pool path; compile warm-up differs
                            per mode (fresh node serials → fresh plans), so
                            this row is reported, not gated

A violated gate raises, which makes `benchmarks/run.py` — and the CI bench
smoke job — exit non-zero.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import Orchestrator, Pipeline, Stage, StripeSplitter
from repro.core.process_object import Filter
from repro.raster import ParallelRasterWriter, RasterReader, SyntheticScene

ROWS, COLS = 48, 32


class _SleepIdentity(Filter):
    """Identity with a fixed host-side cost per region (eager stages only)."""

    def __init__(self, seconds: float, name=None):
        super().__init__(name)
        self.seconds = seconds

    def output_info(self, info):
        return info

    def generate(self, out_region, x):
        time.sleep(self.seconds)
        return x


def _sleep_chain(per_region: float, n_splits: int, n_stages: int = 3):
    """n_stages-deep identity chain, every region costing ``per_region``."""

    def make_build(inputs):
        def build(input_paths, out_path):
            p = Pipeline()
            if inputs:
                x = p.add(RasterReader(input_paths[inputs[0]]))
            else:
                x = p.add(
                    SyntheticScene(ROWS, COLS, bands=2, dtype=np.float32)
                )
            x = p.add(_SleepIdentity(per_region), [x])
            m = p.add(ParallelRasterWriter(out_path), [x])
            return p, m

        return build

    stages = []
    for i in range(n_stages):
        inputs = (f"s{i - 1}",) if i else ()
        stages.append(
            Stage(f"s{i}", make_build(inputs), inputs=inputs,
                  splitter=StripeSplitter(n_splits=n_splits), use_jit=False)
        )
    return stages


def _wall(stages, **orch_kw) -> tuple:
    with Orchestrator(stages, **orch_kw) as orch:
        t0 = time.perf_counter()
        orch.run()
        return time.perf_counter() - t0, dict(orch.edge_stats)


def run(quick: bool = False) -> List:
    out = []
    n_stages, capacity = 3, 2
    per_region = 0.02 if quick else 0.05
    n_splits = 6 if quick else 8

    # untimed warm-up with the *same strip geometry* so the first timed run
    # doesn't absorb one-time per-shape eager-dispatch compilation (the
    # barrier run goes first and would otherwise look arbitrarily worse)
    _wall(_sleep_chain(0.0, n_splits, n_stages))

    t_barrier, _ = _wall(_sleep_chain(per_region, n_splits, n_stages))
    t_pipe, stats = _wall(
        _sleep_chain(per_region, n_splits, n_stages),
        pipelined=True, queue_capacity=capacity,
    )
    ratio = t_pipe / t_barrier
    max_in_flight = max(s.max_in_flight for s in stats.values())
    overdrafts = sum(s.overdrafts for s in stats.values())

    out.append(("orch_chain_barrier", t_barrier * 1e6, float(n_stages)))
    out.append(("orch_chain_pipelined", t_pipe * 1e6, ratio))
    out.append(("orch_chain_max_in_flight", float(max_in_flight),
                float(capacity)))

    # acceptance gates (ISSUE 6): pipelining beats the barrier sum by >=25%
    # while never holding more than queue_capacity strips per edge in flight.
    # A failed gate still hands the harness the rows measured so far.
    def _fail(msg):
        err = AssertionError(msg)
        err.partial_rows = list(out)
        raise err

    if ratio >= 0.75:
        _fail(
            f"pipelined/barrier ratio {ratio:.3f} >= 0.75 "
            f"(barrier {t_barrier:.3f}s, pipelined {t_pipe:.3f}s)"
        )
    if max_in_flight > capacity:
        _fail(
            f"max_in_flight {max_in_flight} exceeded queue_capacity "
            f"{capacity} (stats: {stats})"
        )
    if overdrafts:
        _fail(
            f"zero-halo in-order chain must never overdraft; got {overdrafts}"
        )

    if not quick:
        # the real chain (jitted pool stages); fresh node serials mean each
        # mode pays its own compile warm-up, so report without a gate
        from repro import pipelines as PP

        t_real_b, _ = _wall(PP.chain_stages(rows_xs=24, cols_xs=16,
                                            n_splits=6))
        t_real_p, _ = _wall(
            PP.chain_stages(rows_xs=24, cols_xs=16, n_splits=6),
            pipelined=True, queue_capacity=capacity,
        )
        out.append(("orch_chain_real_barrier", t_real_b * 1e6, float(n_stages)))
        out.append(("orch_chain_real_pipelined", t_real_p * 1e6,
                    t_real_p / t_real_b))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
