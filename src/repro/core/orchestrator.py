"""Orchestration of multiple connected pipelines (paper §IV.C) — barrier and
region-granularity pipelined execution of a stage DAG.

``Orchestrator`` runs a DAG of pipeline *stages*: each stage is a pipeline
terminated by a raster writer; downstream stages read the upstream products
(materialized as RTIF files — the cluster-wide exchange medium, the role
GeoTiff plays in the paper's production setting).  Each stage declares its
own worker count / executor kind, so a poorly-scaling stage can run at a
different width than a compute-bound one, and all stages consult one shared
:class:`~repro.core.execplan.PlanCache` (the process-wide registry by
default) so a DAG mixing thread-pool streaming stages (``executor="pool"``)
and shard_map SPMD stages (``executor="spmd"``) shares compiled plans.

Two execution modes:

**Barrier mode** (``pipelined=False``, the differential oracle): stages run
strictly sequentially — a stage starts only after every producer has fully
materialized its output.  A multi-stage job pays the *sum* of stage wall
times and holds whole intermediate images on disk between stages.

**Pipelined mode** (``pipelined=True``): all ready stages run concurrently
and connected stages stream into each other at **region granularity** via
the edge-queue commit protocol (:mod:`repro.core.dag`):

  * every producer→consumer pair gets a bounded :class:`~repro.core.dag.
    EdgeQueue`; the producer's :class:`~repro.raster.io.StripWriter` fires a
    commit notification for rows whose bytes are actually on disk (post
    ``pwrite``/flush — a strip buffered in a coalescing run is *not* yet
    committed, and one flushed run commits as a single row range);
  * consumer workers gate **per region**: the describe pass records the
    exact input rows a region reads (halos and windowed reads included) and
    the :class:`~repro.core.dag.RegionGate` blocks until those rows are
    committed — so a consumer starts pulling the moment its first input
    strip lands, not when the producer finishes;
  * at most ``queue_capacity`` committed-but-unconsumed strips stay in
    flight per edge (backpressure on the producer, armed from edge creation
    and fed in the consumers' row order — producer stages run FIFO); a
    consumer demanding rows *beyond every offered strip* (halo past the
    frontier at capacity 1, a whole-image consumer region) overrides the
    bound so the DAG can never cycle-wait, counted in
    ``EdgeStats.overdrafts``;
  * a failed stage cancels its consumers **with the original exception**
    (:class:`~repro.core.dag.UpstreamFailed`) and aborts every other stage
    (:class:`~repro.core.dag.PipelineCancelled`) instead of hanging them;
    :meth:`Orchestrator.cancel` does the same for a user shutdown.

The end state the ROADMAP asks for: a pansharpen → texture → classify chain
holds at most a few strips of intermediate in flight per edge and its wall
time approaches the *slowest* stage, not the sum (see
``benchmarks/bench_orchestrator.py``, which reproduces the task-parallel vs
data-parallel comparison of the PAPERS.md workflow studies).

Pipelined stage contracts: stage ``build`` callables must be geometry-only
(they run as soon as upstream files have headers, *before* upstream pixels
exist — pixel-dependent setup such as classifier training must happen
before orchestration or inside filters); producer stages must terminate in
a commit-capable writer (:class:`~repro.raster.mappers.ParallelRasterWriter`
or any mapper exposing ``bind_commit_sink``) and split output into
full-width strips; SPMD *consumer* stages gate at stage granularity (their
executor reads the whole input up front) while SPMD producers commit
per-strip like any other stage.

``Orchestrator`` also owns its scratch space: a workdir created by the
orchestrator itself (no ``workdir=`` argument) is removed by
:meth:`cleanup` / the context-manager exit; a caller-supplied workdir is
left alone.
"""
from __future__ import annotations

import dataclasses
import pathlib
import shutil
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.dag import (
    EdgeFanout,
    EdgeQueue,
    EdgeStats,
    PipelineCancelled,
    RegionGate,
    UpstreamFailed,
)
from repro.core.execplan import CacheStats, PlanCache, global_plan_cache
from repro.core.pipeline import Pipeline
from repro.core.process_object import Mapper
from repro.core.splitting import Splitter, StripeSplitter
from repro.core.streaming import run_pool


@dataclasses.dataclass
class Stage:
    """One homogeneous pipeline stage.

    ``build(input_paths: dict[name, path], output_path) -> (Pipeline, Mapper)``
    wires the stage graph, reading its inputs from the given RTIF paths and
    terminating in a writer at ``output_path``.  Under ``pipelined=True``
    the build runs as soon as the input files have headers — it must not
    read input *pixels* (geometry-only, see the module docstring).

    ``scheduler`` picks how the stage's ``n_workers`` threads share regions:
    ``"work_stealing"`` (default — one shared queue, idle workers steal),
    ``"static"`` or ``"lpt"`` (precomputed slices, still run concurrently).
    A pipelined consumer stage is handed regions in readiness (commit) order
    instead — see :func:`~repro.core.streaming.run_pool`.

    ``executor`` selects the execution engine: ``"pool"`` (default — the
    concurrent streaming driver) or ``"spmd"`` (the shard_map
    :class:`~repro.core.parallel.ParallelExecutor` over up to ``n_workers``
    devices).  Both kinds draw compiled plans from the orchestrator's shared
    registry.  ``splitter``, ``scheduler`` and ``use_jit`` only apply to the
    pool engine — an SPMD stage derives its strip geometry from the device
    count and always runs jitted (the orchestrator rejects contradictions).
    """

    name: str
    build: Callable[[Dict[str, str], str], tuple]
    inputs: Sequence[str] = ()  # names of upstream stages
    n_workers: int = 1
    splitter: Optional[Splitter] = None
    scheduler: str = "work_stealing"
    use_jit: bool = True
    executor: str = "pool"


@dataclasses.dataclass
class StageResult:
    name: str
    path: str
    seconds: float  # stage active time (overlaps other stages when pipelined)
    regions: int
    cache_stats: Optional[CacheStats] = None


class _WorkerBudget:
    """Shared worker budget for concurrently-running stages.

    A stage acquires its (clamped) worker count before building and releases
    it when done.  Acquisition order follows data readiness — producers
    begin before their consumers wait on edge-open — so budget waits only
    ever point *up* the DAG and cannot cycle.  ``abort`` wakes all waiters
    into :class:`PipelineCancelled`."""

    def __init__(self, total: Optional[int]):
        self.total = total
        self._free = total if total is not None else 0
        self._cv = threading.Condition()
        self._aborted = False

    def clamp(self, n: int) -> int:
        return n if self.total is None else max(1, min(n, self.total))

    def acquire(self, n: int) -> int:
        n = self.clamp(n)
        if self.total is None:
            return n
        with self._cv:
            while self._free < n and not self._aborted:
                self._cv.wait(0.1)
            if self._aborted:
                raise PipelineCancelled("orchestrator run aborted")
            self._free -= n
        return n

    def release(self, n: int) -> None:
        if self.total is None:
            return
        with self._cv:
            self._free += n
            self._cv.notify_all()

    def abort(self) -> None:
        with self._cv:
            self._aborted = True
            self._cv.notify_all()


class Orchestrator:
    def __init__(
        self,
        stages: Sequence[Stage],
        workdir: Optional[str] = None,
        plan_cache: Optional[PlanCache] = None,
        pipelined: bool = False,
        queue_capacity: int = 2,
        max_workers: Optional[int] = None,
    ):
        self.stages = list(stages)
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError("stage names must be unique")
        known = set()
        for s in self.stages:  # declaration order must be topological
            if s.executor not in ("pool", "spmd"):
                raise ValueError(f"stage {s.name}: unknown executor {s.executor}")
            if s.executor == "spmd" and (
                s.splitter is not None
                or not s.use_jit
                or s.scheduler != "work_stealing"
            ):
                raise ValueError(
                    f"stage {s.name}: splitter/scheduler/use_jit=False are "
                    "pool-only options — the spmd engine derives strip "
                    "geometry from the device count and always runs jitted"
                )
            missing = [i for i in s.inputs if i not in known]
            if missing:
                raise ValueError(f"stage {s.name}: unknown inputs {missing}")
            known.add(s.name)
        if queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        if max_workers is not None and max_workers < 1:
            raise ValueError("max_workers must be >= 1 (or None for unbounded)")
        self._owns_workdir = workdir is None
        self.workdir = pathlib.Path(workdir or tempfile.mkdtemp(prefix="orch_"))
        self.workdir.mkdir(parents=True, exist_ok=True)
        # one registry across every stage and executor kind (process-wide by
        # default): streaming and SPMD stages share compiled plans
        self.plan_cache = plan_cache if plan_cache is not None else global_plan_cache()
        self.pipelined = pipelined
        self.queue_capacity = queue_capacity
        self.max_workers = max_workers
        #: (producer, consumer) -> EdgeStats of the last pipelined run
        self.edge_stats: Dict[Tuple[str, str], EdgeStats] = {}
        self._active_edges: List[EdgeQueue] = []
        self._active_budget: Optional[_WorkerBudget] = None
        self._cancelled = threading.Event()

    # -- lifecycle -------------------------------------------------------------
    def cleanup(self) -> None:
        """Remove the workdir if this orchestrator created it (the
        ``tempfile.mkdtemp`` default); caller-supplied workdirs are left
        alone.  Idempotent."""
        if self._owns_workdir and self.workdir.exists():
            shutil.rmtree(self.workdir, ignore_errors=True)

    def __enter__(self) -> "Orchestrator":
        return self

    def __exit__(self, *exc) -> None:
        self.cleanup()

    def cancel(self) -> None:
        """Abort an in-flight pipelined run: every blocked producer/consumer
        unwinds with :class:`PipelineCancelled` instead of hanging."""
        self._cancelled.set()
        exc = PipelineCancelled("cancelled by Orchestrator.cancel()")
        for edge in list(self._active_edges):
            edge.cancel(exc)
        budget = self._active_budget
        if budget is not None:
            budget.abort()

    # -- single-stage execution ------------------------------------------------
    def _run_stage(
        self,
        stage: Stage,
        pipeline: Pipeline,
        mapper: Mapper,
        n_workers: Optional[int] = None,
        region_gate: Optional[RegionGate] = None,
        in_order: bool = False,
    ):
        if stage.executor == "spmd":
            import jax

            from repro.core.parallel import ParallelExecutor

            devices = jax.devices()[: max(1, stage.n_workers)]
            return ParallelExecutor(
                pipeline, mapper, devices=devices, plan_cache=self.plan_cache
            ).run()
        workers = n_workers if n_workers is not None else stage.n_workers
        splitter = stage.splitter or StripeSplitter(
            n_splits=max(4, stage.n_workers * 4)
        )
        # the stage's workers run concurrently against one shared region
        # queue (work stealing / readiness order) or their schedule slices,
        # with the orchestrator-wide PlanCache — a uniform split compiles once
        return run_pool(
            pipeline, mapper, splitter,
            n_workers=workers,
            scheduler=stage.scheduler,
            use_jit=stage.use_jit,
            plan_cache=self.plan_cache,
            region_gate=region_gate,
            in_order=in_order,
        )

    # -- barrier mode (the differential oracle) --------------------------------
    def _run_barrier(self, verbose: bool) -> Dict[str, StageResult]:
        paths: Dict[str, str] = {}
        results: Dict[str, StageResult] = {}
        for stage in self.stages:
            out_path = str(self.workdir / f"{stage.name}.rtif")
            pipeline, mapper = stage.build(
                {i: paths[i] for i in stage.inputs}, out_path
            )
            t0 = time.perf_counter()
            res = self._run_stage(stage, pipeline, mapper)
            dt = time.perf_counter() - t0
            paths[stage.name] = out_path
            results[stage.name] = StageResult(
                stage.name, out_path, dt, res.regions_processed, res.cache_stats
            )
            if verbose:
                print(f"[orchestrator] {stage.name}: {res.regions_processed} "
                      f"regions in {dt:.2f}s → {out_path}")
        return results

    # -- pipelined mode --------------------------------------------------------
    def _run_pipelined(self, verbose: bool) -> Dict[str, StageResult]:
        consumers_of: Dict[str, List[str]] = {s.name: [] for s in self.stages}
        for s in self.stages:
            for i in s.inputs:
                consumers_of[i].append(s.name)
        edges: Dict[Tuple[str, str], EdgeQueue] = {
            (i, s.name): EdgeQueue(i, s.name, self.queue_capacity)
            for s in self.stages
            for i in s.inputs
        }
        # arm backpressure NOW for region-granular (pool) consumers: their
        # producers then never run more than queue_capacity strips ahead,
        # even during the consumer's build/warm-up window.  SPMD consumers
        # gate at stage granularity, so their edges stay unthrottled.
        for s in self.stages:
            if s.executor == "pool":
                for i in s.inputs:
                    edges[(i, s.name)].consumer_started()
        paths = {s.name: str(self.workdir / f"{s.name}.rtif") for s in self.stages}
        results: Dict[str, StageResult] = {}
        errors: Dict[str, BaseException] = {}
        budget = _WorkerBudget(self.max_workers)
        self.edge_stats = {k: e.stats for k, e in edges.items()}
        self._active_edges = list(edges.values())
        self._active_budget = budget
        self._cancelled.clear()
        lock = threading.Lock()  # guards results/errors across stage threads

        def abort_all(exc: BaseException) -> None:
            for e in edges.values():
                e.cancel(exc)
            budget.abort()

        def run_stage(stage: Stage) -> None:
            inbound = {i: edges[(i, stage.name)] for i in stage.inputs}
            outbound = [edges[(stage.name, c)] for c in consumers_of[stage.name]]
            fanout = EdgeFanout(outbound) if outbound else None
            acquired = 0
            try:
                # producers open their edge at mapper.begin — only then does
                # the consumer's build see a readable RTIF header
                for e in inbound.values():
                    e.wait_open()
                acquired = budget.acquire(
                    stage.n_workers if stage.executor == "pool" else 1
                )
                pipeline, mapper = stage.build(
                    {i: paths[i] for i in stage.inputs}, paths[stage.name]
                )
                if fanout is not None:
                    if not hasattr(mapper, "bind_commit_sink"):
                        raise ValueError(
                            f"stage {stage.name}: pipelined producer stages "
                            "must terminate in a commit-capable writer "
                            "(ParallelRasterWriter or a mapper exposing "
                            "bind_commit_sink) — got "
                            f"{type(mapper).__name__}"
                        )
                    mapper.bind_commit_sink(fanout)
                t0 = time.perf_counter()
                if stage.executor == "spmd":
                    # the SPMD executor reads its whole input up front:
                    # stage-granularity gating, and no backpressure upstream
                    # (consumer_started is never signalled)
                    for e in inbound.values():
                        e.wait_complete()
                    res = self._run_stage(stage, pipeline, mapper)
                else:
                    gate = (
                        RegionGate(
                            {paths[i]: e for i, e in inbound.items()}
                        )
                        if inbound
                        else None
                    )
                    for e in inbound.values():
                        e.consumer_started()
                    res = self._run_stage(
                        stage, pipeline, mapper,
                        n_workers=acquired, region_gate=gate,
                        # producers offer strips in the consumers' row order:
                        # backpressure then tracks the real commit frontier
                        # and max_in_flight stays at queue_capacity
                        in_order=bool(outbound),
                    )
                dt = time.perf_counter() - t0
                for e in inbound.values():
                    e.consumer_finished()
                if fanout is not None:
                    # run_pool/ParallelExecutor already closed the writer
                    # (mapper.end → StripWriter.close → final flush), so every
                    # commit has fired; mark the edges complete
                    fanout.close()
                with lock:
                    results[stage.name] = StageResult(
                        stage.name, paths[stage.name], dt,
                        res.regions_processed, res.cache_stats,
                    )
                if verbose:
                    print(f"[orchestrator] {stage.name}: "
                          f"{res.regions_processed} regions in {dt:.2f}s → "
                          f"{paths[stage.name]}")
            except BaseException as exc:  # noqa: BLE001 — crosses threads
                with lock:
                    errors[stage.name] = exc
                if fanout is not None:
                    fanout.fail(stage.name, exc)  # consumers: UpstreamFailed
                abort_all(exc)  # everyone else: PipelineCancelled
            finally:
                if acquired:
                    budget.release(acquired)

        threads = [
            threading.Thread(
                target=run_stage, args=(s,), name=f"stage:{s.name}", daemon=True
            )
            for s in self.stages
        ]
        try:
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        finally:
            self._active_edges = []
            self._active_budget = None
        if errors:
            # surface the ROOT failure: a consumer cancelled by its producer
            # re-raises the producer's original exception, not the wrapper
            root = None
            for exc in errors.values():
                if not isinstance(exc, (UpstreamFailed, PipelineCancelled)):
                    root = exc
                    break
            if root is None:
                for exc in errors.values():
                    if isinstance(exc, UpstreamFailed):
                        root = exc.cause
                        break
            raise root if root is not None else next(iter(errors.values()))
        return results

    def run(
        self, verbose: bool = False, pipelined: Optional[bool] = None
    ) -> Dict[str, StageResult]:
        """Execute the stage DAG; returns per-stage results keyed by name.

        ``pipelined`` overrides the constructor default for this run:
        ``False`` is the sequential barrier oracle, ``True`` streams
        connected stages into each other at region granularity.  After a
        pipelined run, :attr:`edge_stats` holds per-edge counters
        (``max_in_flight``, ``commits``, ``waits``, ``overdrafts``)."""
        mode = self.pipelined if pipelined is None else pipelined
        if mode:
            return self._run_pipelined(verbose)
        return self._run_barrier(verbose)
