"""Orchestration of multiple connected pipelines (the paper's §IV.C second
future-work item).

The paper recommends splitting heterogeneous pipelines "in multiple
homogeneous parts with uniform scalability and to run them sequentially",
and asks for "the orchestration of multiple connected pipelines execution".
``Orchestrator`` runs a DAG of pipeline *stages*: each stage is a pipeline
terminated by a raster writer; downstream stages read the upstream products
(materialized as RTIF files — the cluster-wide exchange medium, exactly the
role GeoTiff plays in the paper's production setting).  Each stage declares
its own worker count / executor kind, so a poorly-scaling stage (paper:
heavy-I/O or non-parallelizable filters) can run at a different width than
a compute-bound one.

All stages consult one shared :class:`~repro.core.execplan.PlanCache` (the
process-wide registry by default), so a DAG mixing thread-pool streaming
stages (``executor="pool"``) and shard_map SPMD stages (``executor="spmd"``)
shares compiled plans: a stage graph already traced by one executor kind is
a registry hit for the other on matching strip geometry.
"""
from __future__ import annotations

import dataclasses
import pathlib
import tempfile
import time
from typing import Callable, Dict, Optional, Sequence

from repro.core.execplan import CacheStats, PlanCache, global_plan_cache
from repro.core.pipeline import Pipeline
from repro.core.process_object import Mapper
from repro.core.splitting import Splitter, StripeSplitter
from repro.core.streaming import run_pool


@dataclasses.dataclass
class Stage:
    """One homogeneous pipeline stage.

    ``build(input_paths: dict[name, path], output_path) -> (Pipeline, Mapper)``
    wires the stage graph, reading its inputs from the given RTIF paths and
    terminating in a writer at ``output_path``.

    ``scheduler`` picks how the stage's ``n_workers`` threads share regions:
    ``"work_stealing"`` (default — one shared queue, idle workers steal),
    ``"static"`` or ``"lpt"`` (precomputed slices, still run concurrently).

    ``executor`` selects the execution engine: ``"pool"`` (default — the
    concurrent streaming driver) or ``"spmd"`` (the shard_map
    :class:`~repro.core.parallel.ParallelExecutor` over up to ``n_workers``
    devices).  Both kinds draw compiled plans from the orchestrator's shared
    registry.  ``splitter``, ``scheduler`` and ``use_jit`` only apply to the
    pool engine — an SPMD stage derives its strip geometry from the device
    count and always runs jitted (the orchestrator rejects contradictions).
    """

    name: str
    build: Callable[[Dict[str, str], str], tuple]
    inputs: Sequence[str] = ()  # names of upstream stages
    n_workers: int = 1
    splitter: Optional[Splitter] = None
    scheduler: str = "work_stealing"
    use_jit: bool = True
    executor: str = "pool"


@dataclasses.dataclass
class StageResult:
    name: str
    path: str
    seconds: float
    regions: int
    cache_stats: Optional[CacheStats] = None


class Orchestrator:
    def __init__(
        self,
        stages: Sequence[Stage],
        workdir: Optional[str] = None,
        plan_cache: Optional[PlanCache] = None,
    ):
        self.stages = list(stages)
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError("stage names must be unique")
        known = set()
        for s in self.stages:  # declaration order must be topological
            if s.executor not in ("pool", "spmd"):
                raise ValueError(f"stage {s.name}: unknown executor {s.executor}")
            if s.executor == "spmd" and (
                s.splitter is not None
                or not s.use_jit
                or s.scheduler != "work_stealing"
            ):
                raise ValueError(
                    f"stage {s.name}: splitter/scheduler/use_jit=False are "
                    "pool-only options — the spmd engine derives strip "
                    "geometry from the device count and always runs jitted"
                )
            missing = [i for i in s.inputs if i not in known]
            if missing:
                raise ValueError(f"stage {s.name}: unknown inputs {missing}")
            known.add(s.name)
        self.workdir = pathlib.Path(workdir or tempfile.mkdtemp(prefix="orch_"))
        self.workdir.mkdir(parents=True, exist_ok=True)
        # one registry across every stage and executor kind (process-wide by
        # default): streaming and SPMD stages share compiled plans
        self.plan_cache = plan_cache if plan_cache is not None else global_plan_cache()

    def _run_stage(self, stage: Stage, pipeline: Pipeline, mapper: Mapper):
        if stage.executor == "spmd":
            import jax

            from repro.core.parallel import ParallelExecutor

            devices = jax.devices()[: max(1, stage.n_workers)]
            return ParallelExecutor(
                pipeline, mapper, devices=devices, plan_cache=self.plan_cache
            ).run()
        splitter = stage.splitter or StripeSplitter(
            n_splits=max(4, stage.n_workers * 4)
        )
        # the stage's workers run concurrently against one shared region
        # queue (work stealing) or their schedule slices, with the
        # orchestrator-wide PlanCache — a uniform split compiles once
        return run_pool(
            pipeline, mapper, splitter,
            n_workers=stage.n_workers,
            scheduler=stage.scheduler,
            use_jit=stage.use_jit,
            plan_cache=self.plan_cache,
        )

    def run(self, verbose: bool = False) -> Dict[str, StageResult]:
        paths: Dict[str, str] = {}
        results: Dict[str, StageResult] = {}
        for stage in self.stages:
            out_path = str(self.workdir / f"{stage.name}.rtif")
            pipeline, mapper = stage.build(
                {i: paths[i] for i in stage.inputs}, out_path
            )
            t0 = time.time()
            res = self._run_stage(stage, pipeline, mapper)
            dt = time.time() - t0
            paths[stage.name] = out_path
            results[stage.name] = StageResult(
                stage.name, out_path, dt, res.regions_processed, res.cache_stats
            )
            if verbose:
                print(f"[orchestrator] {stage.name}: {res.regions_processed} "
                      f"regions in {dt:.2f}s → {out_path}")
        return results
