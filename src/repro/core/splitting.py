"""Splitting strategies (paper §II.B, §II.D).

The mapper chooses how the output image is divided into regions: striped or
tiled with fixed dimensions, or automatically from the memory specification
and the number of workers.  Every splitter must tile the domain *exactly*
(cover every pixel once) — property-tested in tests/test_splitting.py.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from repro.core.process_object import ImageInfo
from repro.core.region import ImageRegion


class Splitter:
    def split(self, region: ImageRegion, info: ImageInfo) -> List[ImageRegion]:
        raise NotImplementedError


class RowCoverage:
    """A monotone set of committed row intervals (half-open ``[lo, hi)``).

    The region-granularity DAG scheduler tracks which output rows a producer
    stage has committed to disk; consumers derive readiness from it.  Commits
    may arrive out of order (work-stealing producers, coalesced write-behind
    runs), so coverage is a sorted list of disjoint intervals that merges
    neighbors on insert.  Not thread-safe — callers (the edge queues) hold
    their own lock.
    """

    def __init__(self) -> None:
        self._ivals: List[List[int]] = []  # sorted, disjoint, non-adjacent

    def add(self, lo: int, hi: int) -> None:
        """Mark rows ``[lo, hi)`` covered (idempotent, merges neighbors)."""
        if hi <= lo:
            return
        out: List[List[int]] = []
        inserted = False
        for a, b in self._ivals:
            if b < lo or hi < a:  # disjoint and non-adjacent: keep as-is
                if a > hi and not inserted:
                    out.append([lo, hi])
                    inserted = True
                out.append([a, b])
            else:  # overlap or touch: absorb into the new interval
                lo, hi = min(lo, a), max(hi, b)
        if not inserted:
            out.append([lo, hi])
            out.sort()
        self._ivals = out

    def covers(self, lo: int, hi: int) -> bool:
        """True when every row of ``[lo, hi)`` is covered."""
        if hi <= lo:
            return True
        for a, b in self._ivals:
            if a <= lo and hi <= b:
                return True
            if a > lo:
                break
        return False

    def covered_rows(self) -> int:
        return sum(b - a for a, b in self._ivals)

    def intervals(self) -> List[tuple]:
        return [(a, b) for a, b in self._ivals]

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"RowCoverage({self._ivals})"


def padded_tile_grid(
    rows: int, cols: int, nr: int, nc: int
) -> tuple[int, int, int, int]:
    """Uniform SPMD tile geometry for a ``rows × cols`` output over an
    ``nr × nc`` worker grid: ``(Hr, Wc, pad_rows, pad_cols)`` with
    ``Hr = ceil(rows / nr)``, ``Wc = ceil(cols / nc)`` and the pads the
    trailing *virtual* rows/cols past the image (``nr·Hr − rows`` and
    ``nc·Wc − cols``).

    This is the geometry contract of the virtual-padded-tile SPMD path:
    every worker owns one ``Hr × Wc`` tile of the virtually padded image,
    the padded global input is edge-replicated over the pad rows *and*
    pad columns, and the executor crops/masks the pad before the write
    stage.  The 1-D strip path is exactly the ``nc = 1`` column of this
    grid."""
    if rows <= 0 or cols <= 0 or nr <= 0 or nc <= 0:
        raise ValueError("rows, cols, nr and nc must be positive")
    Hr = math.ceil(rows / nr)
    Wc = math.ceil(cols / nc)
    return Hr, Wc, nr * Hr - rows, nc * Wc - cols


def virtual_tile_regions(
    rows: int, cols: int, nr: int, nc: int
) -> List[ImageRegion]:
    """The ``nr × nc`` uniform virtual tiles of a ``rows × cols`` output in
    row-major order: tile ``(i, j)`` is ``[i·Hr, (i+1)·Hr) × [j·Wc,
    (j+1)·Wc)`` — edge tiles may spill past the image in either axis (use
    :func:`padded_tile_grid` for the pad sizes).  Shared by the SPMD tile
    prober and the virtual describe pass so both see identical per-worker
    geometry."""
    Hr, Wc, _, _ = padded_tile_grid(rows, cols, nr, nc)
    return [
        ImageRegion((i * Hr, j * Wc), (Hr, Wc))
        for i in range(nr)
        for j in range(nc)
    ]


def clamped_tile_spans(lo: int, hi: int, step: int) -> List[tuple[int, int]]:
    """``(start, size)`` spans of width ``step`` covering ``[lo, hi)``
    exactly, the last span clamped to the boundary.  The shared 1-axis
    clamping primitive behind :class:`StripeSplitter` / :class:`TileSplitter`
    (real, in-image tiles) — contrast :func:`virtual_tile_regions`, whose
    tiles never clamp."""
    if step <= 0:
        raise ValueError("step must be positive")
    return [(a, min(step, hi - a)) for a in range(lo, hi, step)]


def padded_strip_rows(rows: int, n_workers: int) -> tuple[int, int]:
    """Uniform SPMD strip height + virtual row padding for ``rows`` output
    rows over ``n_workers`` strips: ``(H, pad)`` with ``H = ceil(rows / n)``
    and ``pad = n·H − rows`` trailing *virtual* rows past the image.
    The ``nc = 1`` special case of :func:`padded_tile_grid`."""
    Hr, _, pad_rows, _ = padded_tile_grid(rows, 1, n_workers, 1)
    return Hr, pad_rows


def virtual_strip_regions(
    rows: int, cols: int, n_workers: int
) -> List[ImageRegion]:
    """The ``n_workers`` uniform virtual strips of a ``rows × cols`` output:
    strip ``k`` is ``[k·H, (k+1)·H) × [0, cols)`` — the last strip(s) may
    spill past ``rows``.  The ``nc = 1`` special case of
    :func:`virtual_tile_regions`."""
    return virtual_tile_regions(rows, cols, n_workers, 1)


class StripeSplitter(Splitter):
    """Horizontal strips — the paper's row-wise scheme (fast for the
    row-interleaved GeoTiff layout, §II.D [16])."""

    def __init__(self, n_splits: int | None = None, stripe_rows: int | None = None):
        if (n_splits is None) == (stripe_rows is None):
            raise ValueError("specify exactly one of n_splits / stripe_rows")
        self.n_splits = n_splits
        self.stripe_rows = stripe_rows

    def split(self, region: ImageRegion, info: ImageInfo) -> List[ImageRegion]:
        rows = region.rows
        if self.stripe_rows is not None:
            step = max(1, self.stripe_rows)
        else:
            step = max(1, math.ceil(rows / max(1, self.n_splits)))
        return [
            ImageRegion((r, region.col0), (h, region.cols))
            for r, h in clamped_tile_spans(region.row0, region.row1, step)
        ]


class TileSplitter(Splitter):
    """Fixed-dimension tiles."""

    def __init__(self, tile_rows: int, tile_cols: int):
        if tile_rows <= 0 or tile_cols <= 0:
            raise ValueError("tile dims must be positive")
        self.tile_rows = tile_rows
        self.tile_cols = tile_cols

    def split(self, region: ImageRegion, info: ImageInfo) -> List[ImageRegion]:
        return [
            ImageRegion((r, c), (h, w))
            for r, h in clamped_tile_spans(region.row0, region.row1, self.tile_rows)
            for c, w in clamped_tile_spans(region.col0, region.col1, self.tile_cols)
        ]


class AutoSplitter(Splitter):
    """Paper §II.D: split count "automatically computed using the system
    specifications (memory and number of MPI processes)".

    Chooses striped regions such that one region's pixel buffer fits in
    ``memory_budget_bytes`` and the number of splits is a multiple of
    ``n_workers`` (so the static schedule is balanced)."""

    def __init__(self, memory_budget_bytes: int, n_workers: int = 1):
        if memory_budget_bytes <= 0 or n_workers <= 0:
            raise ValueError("budget and n_workers must be positive")
        self.memory_budget_bytes = memory_budget_bytes
        self.n_workers = n_workers

    def split(self, region: ImageRegion, info: ImageInfo) -> List[ImageRegion]:
        bytes_per_row = max(1, region.cols * info.bytes_per_pixel)
        rows_per_split = max(1, self.memory_budget_bytes // bytes_per_row)
        n = math.ceil(region.rows / rows_per_split)
        # round the split count UP to a multiple of n_workers for balance
        n = max(self.n_workers, math.ceil(n / self.n_workers) * self.n_workers)
        n = min(n, region.rows) if region.rows > 0 else n
        return StripeSplitter(n_splits=n).split(region, info)


class VMEMTileSplitter(Splitter):
    """TPU-adapted auto splitter: two-level budget.  Picks MXU-aligned tiles
    (multiples of ``align``, default 128 lanes) whose working set fits a VMEM
    budget — the same planner feeds Pallas BlockSpec shapes."""

    def __init__(self, vmem_budget_bytes: int = 64 * 2**20, align: int = 128):
        self.vmem_budget_bytes = vmem_budget_bytes
        self.align = align

    def split(self, region: ImageRegion, info: ImageInfo) -> List[ImageRegion]:
        bpp = info.bytes_per_pixel
        side = int(math.sqrt(self.vmem_budget_bytes / max(1, bpp)))
        side = max(self.align, (side // self.align) * self.align)
        return TileSplitter(side, side).split(region, info)
