"""Load-balancing schedules for region execution.

The paper's writer "has a static load balancing, meaning that each process has
a fixed processing schedule" (§II.D) and names dynamic balancing as future
work (§IV.C) for "algorithms running in a non-constant time on different image
regions".  We implement the paper's static schedule plus beyond-paper
schedulers: cost-weighted static, LPT, and work stealing — the latter both as
a simulated static assignment (``work_stealing_schedule``, for rank slicing
and makespan analysis) and as a thread-safe runtime queue
(:class:`WorkStealingQueue`, drained by ``run_pool``'s concurrent workers).
"""
from __future__ import annotations

import collections
import heapq
import threading
from typing import Callable, List, Optional, Sequence

from repro.core.region import ImageRegion


def static_schedule(regions: Sequence[ImageRegion], n_workers: int) -> List[List[int]]:
    """Paper-faithful: fixed blocked assignment — worker w gets the w-th
    contiguous run of regions (contiguity keeps each process's file strips
    adjacent, which is what makes the MPI-IO row-interleaved write fast)."""
    n = len(regions)
    base, extra = divmod(n, n_workers)
    out, start = [], 0
    for w in range(n_workers):
        cnt = base + (1 if w < extra else 0)
        out.append(list(range(start, start + cnt)))
        start += cnt
    return out


def cost_weighted_static_schedule(
    regions: Sequence[ImageRegion],
    n_workers: int,
    cost_fn: Callable[[ImageRegion], float],
) -> List[List[int]]:
    """Beyond-paper: contiguous split with balanced *cost* (not count) —
    handles rows with different per-pixel cost (e.g. nodata-heavy strips)
    while preserving contiguity for the parallel writer."""
    costs = [max(1e-12, float(cost_fn(r))) for r in regions]
    total = sum(costs)
    target = total / n_workers
    out: List[List[int]] = [[] for _ in range(n_workers)]
    w, acc = 0, 0.0
    for i in range(len(regions)):
        # move to next worker when current one reached its share (keep at least
        # one region per worker while regions remain to fill all workers)
        remaining_workers = n_workers - w - 1
        remaining_regions = len(regions) - i
        if acc >= target and remaining_workers > 0 and remaining_regions > remaining_workers:
            w += 1
            acc = 0.0
        out[w].append(i)
        acc += costs[i]
    return out


def lpt_schedule(
    regions: Sequence[ImageRegion],
    n_workers: int,
    cost_fn: Callable[[ImageRegion], float],
) -> List[List[int]]:
    """Beyond-paper dynamic-style balancing: Longest-Processing-Time greedy —
    the classic 4/3-approximation to makespan.  Non-contiguous, so it pairs
    with the tile-indexed writer rather than strip-adjacent writes."""
    order = sorted(range(len(regions)), key=lambda i: -cost_fn(regions[i]))
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    out: List[List[int]] = [[] for _ in range(n_workers)]
    for i in order:
        load, w = heapq.heappop(heap)
        out[w].append(i)
        heapq.heappush(heap, (load + float(cost_fn(regions[i])), w))
    for lst in out:
        lst.sort()
    return out


def work_stealing_schedule(
    regions: Sequence[ImageRegion],
    n_workers: int,
    cost_fn: Callable[[ImageRegion], float],
) -> List[List[int]]:
    """The static mirror of work stealing: greedy list scheduling in queue
    order — each region goes to the worker that frees up first, which is the
    assignment an idealized shared-queue run converges to.  Graham's bound
    applies: makespan ≤ total/m + (1 − 1/m)·max_cost ≤ (2 − 1/m)·OPT."""
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    out: List[List[int]] = [[] for _ in range(n_workers)]
    for i, r in enumerate(regions):
        load, w = heapq.heappop(heap)
        out[w].append(i)
        heapq.heappush(heap, (load + max(1e-12, float(cost_fn(r))), w))
    return out


class WorkStealingQueue:
    """Thread-safe dynamic scheduler (the paper's §IV.C named future work).

    Item indices are seeded across per-worker deques with the contiguous
    static schedule (so when costs are uniform, workers keep the
    strip-adjacent access pattern the parallel writer likes).  An owner pops
    from the *front* of its own deque; a worker whose deque is empty steals
    *half* of the victim with the most remaining cost — the tail block, in
    original order, so both halves keep their strip adjacency.  Stealing half
    (rather than one) makes the number of steal operations — and therefore
    lock acquisitions — logarithmic instead of linear in the imbalance, which
    is what keeps lock traffic negligible on very fine splits.  ``steals``
    counts steal operations; ``items_stolen`` counts transferred items."""

    def __init__(
        self,
        n_items: int,
        n_workers: int,
        costs: Optional[Sequence[float]] = None,
    ):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self._costs = (
            [float(c) for c in costs] if costs is not None else [1.0] * n_items
        )
        if len(self._costs) != n_items:
            raise ValueError("costs must have one entry per item")
        seed = static_schedule(range(n_items), n_workers)  # type: ignore[arg-type]
        self._deques = [collections.deque(idxs) for idxs in seed]
        self._remaining = [sum(self._costs[i] for i in idxs) for idxs in seed]
        self._lock = threading.Lock()
        self.steals = 0
        self.items_stolen = 0

    def take(self, worker: int) -> Optional[int]:
        """Next item index for ``worker``; None when the whole queue is dry."""
        with self._lock:
            dq = self._deques[worker]
            if dq:
                i = dq.popleft()
                self._remaining[worker] -= self._costs[i]
                return i
            victim = -1
            best = 0.0
            for w, other in enumerate(self._deques):
                if other and (victim < 0 or self._remaining[w] > best):
                    victim, best = w, self._remaining[w]
            if victim < 0:
                return None
            vd = self._deques[victim]
            half = (len(vd) + 1) // 2  # steal half, at least one
            block = [vd.pop() for _ in range(half)][::-1]  # tail, in order
            moved = sum(self._costs[i] for i in block)
            self._remaining[victim] -= moved
            self.steals += 1
            self.items_stolen += half
            first, rest = block[0], block[1:]
            if rest:
                dq.extend(rest)
                self._remaining[worker] += moved - self._costs[first]
            return first


class FifoQueue:
    """Shared strictly-in-order queue: every worker takes the next unclaimed
    item.  Used by gated (pipelined-DAG) pool runs, where regions sorted by
    row offset become ready in roughly commit order — handing them out in
    that order keeps consumer workers on *ready* regions instead of parking
    each worker at its static block start far ahead of the producer's commit
    frontier (which would defeat both pipelining and the bounded in-flight
    window)."""

    def __init__(self, n_items: int):
        self._n = n_items
        self._next = 0
        self._lock = threading.Lock()

    def take(self, worker: int) -> Optional[int]:
        with self._lock:
            if self._next >= self._n:
                return None
            i = self._next
            self._next += 1
            return i


def makespan(
    schedule: List[List[int]],
    regions: Sequence[ImageRegion],
    cost_fn: Callable[[ImageRegion], float],
) -> float:
    return max(
        (sum(cost_fn(regions[i]) for i in lst) for lst in schedule if lst),
        default=0.0,
    )
