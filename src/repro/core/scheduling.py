"""Load-balancing schedules for region execution.

The paper's writer "has a static load balancing, meaning that each process has
a fixed processing schedule" (§II.D) and names dynamic balancing as future
work (§IV.C) for "algorithms running in a non-constant time on different image
regions".  We implement the paper's static schedule plus two beyond-paper
schedulers.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Sequence

from repro.core.region import ImageRegion


def static_schedule(regions: Sequence[ImageRegion], n_workers: int) -> List[List[int]]:
    """Paper-faithful: fixed blocked assignment — worker w gets the w-th
    contiguous run of regions (contiguity keeps each process's file strips
    adjacent, which is what makes the MPI-IO row-interleaved write fast)."""
    n = len(regions)
    base, extra = divmod(n, n_workers)
    out, start = [], 0
    for w in range(n_workers):
        cnt = base + (1 if w < extra else 0)
        out.append(list(range(start, start + cnt)))
        start += cnt
    return out


def cost_weighted_static_schedule(
    regions: Sequence[ImageRegion],
    n_workers: int,
    cost_fn: Callable[[ImageRegion], float],
) -> List[List[int]]:
    """Beyond-paper: contiguous split with balanced *cost* (not count) —
    handles rows with different per-pixel cost (e.g. nodata-heavy strips)
    while preserving contiguity for the parallel writer."""
    costs = [max(1e-12, float(cost_fn(r))) for r in regions]
    total = sum(costs)
    target = total / n_workers
    out: List[List[int]] = [[] for _ in range(n_workers)]
    w, acc = 0, 0.0
    for i in range(len(regions)):
        # move to next worker when current one reached its share (keep at least
        # one region per worker while regions remain to fill all workers)
        remaining_workers = n_workers - w - 1
        remaining_regions = len(regions) - i
        if acc >= target and remaining_workers > 0 and remaining_regions > remaining_workers:
            w += 1
            acc = 0.0
        out[w].append(i)
        acc += costs[i]
    return out


def lpt_schedule(
    regions: Sequence[ImageRegion],
    n_workers: int,
    cost_fn: Callable[[ImageRegion], float],
) -> List[List[int]]:
    """Beyond-paper dynamic-style balancing: Longest-Processing-Time greedy —
    the classic 4/3-approximation to makespan.  Non-contiguous, so it pairs
    with the tile-indexed writer rather than strip-adjacent writes."""
    order = sorted(range(len(regions)), key=lambda i: -cost_fn(regions[i]))
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    out: List[List[int]] = [[] for _ in range(n_workers)]
    for i in order:
        load, w = heapq.heappop(heap)
        out[w].append(i)
        heapq.heappush(heap, (load + float(cost_fn(regions[i])), w))
    for lst in out:
        lst.sort()
    return out


def makespan(
    schedule: List[List[int]],
    regions: Sequence[ImageRegion],
    cost_fn: Callable[[ImageRegion], float],
) -> float:
    return max(
        (sum(cost_fn(regions[i]) for i in lst) for lst in schedule if lst),
        default=0.0,
    )
