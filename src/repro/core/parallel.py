"""Cluster-parallel pipeline execution (paper §II.C) — TPU/JAX-native.

The paper runs one *pipeline replica per MPI process*, each producing a
different strip of the output; persistent filters aggregate state with MPI
collectives.  Here the whole pipeline is traced once into a *local strip
function* and partitioned with ``shard_map`` over a mesh axis:

  * the output domain is decomposed into ``n`` contiguous block-rows
    (paper's striped splitting scheme, one per device);
  * requested-region propagation is evaluated symbolically for *every*
    worker to derive, per source, the strip pitch (resolution scale) and the
    halo each device must fetch from its neighbors — the MPI point-to-point
    of the paper becomes ``lax.ppermute`` neighbor exchange;
  * boundary devices edge-replicate their own rows (ITK boundary condition),
    so the parallel result matches the streamed oracle — the paper's
    region-independence invariant (§II.C.1);
  * persistent filters accumulate per-device state which is combined with
    ``lax.psum`` / ``pmax`` / ``pmin`` / ``all_gather`` (the paper's
    many-to-one / many-to-many MPI patterns), then ``synthesize`` runs once.

Two kinds of reads feed filters:

  * *covariant reads* — the request shifts by a constant integer pitch per
    worker with constant size (box filters, integer-ratio resampling).  The
    planner slices the exact requested window from the haloed local shard;
    this is checked against the probes of all workers.
  * *windowed reads* — requests of ``needs_origin`` filters (warps) whose
    exact windows drift fractionally per worker.  The describe pass lowers
    them to the plan layer's *window specs* (``ProcessObject.window_bound``):
    conservative static-shape bounding windows whose absolute origins are
    traced scalars.  Constant shape means one canonical plan for every
    interior strip; the per-worker window origin becomes a constant table
    gathered at the mesh index, and the window itself is a
    ``lax.dynamic_slice`` of the halo-exchanged local shard.

Anything else (data-dependent regions, non-affine request growth, drifting
``needs_origin`` reads without a ``window_bound``, per-strip plan keys)
raises ``NotStripParallelizable`` and should run through the streaming
driver.

**Unified ExecutionPlan path** — the *only* strip path.  ``build_strip_plan``
runs the cheap describe pass (``Pipeline.describe_pull``) for every worker
strip against the **virtual padded geometry** (rows padded up to ``n × H``,
``H = ceil(rows / n)``; the describe walk never clamps rows), so every strip
— the ragged last one of an uneven split and both border strips of an n=2
halo split included — yields the *interior* plan signature.  All strips must
share that one signature; the strip body is then fetched from the shared
:class:`~repro.core.execplan.PlanCache` — the very same registry (and the
very same lowered closure) the streaming engine uses.  A pipeline streamed
first and then run SPMD on any strip geometry is therefore a registry *hit*:
no new describe→lower pass, no new closure tree.  Per-strip ``needs_origin``
coordinates (covariant, window *and* persistent-mask origins alike) are
threaded as per-worker constant tables indexed by the mesh index; plan reads
are static slices of the halo-exchanged local shard when their offsets are
strip-invariant and ``lax.dynamic_slice`` windows otherwise.  Row spill past
the real image — border halos and virtual pad rows — is materialized at the
read stage (edge-padded global + halo edge replication), never in the trace.
Masked-persistent accumulation is the only special case left, and it runs
through the same registry body: mask-aware filters accumulate under an
in-trace validity mask derived from their traced row origin, so pad rows
never contaminate reduced state; the executor crops pad rows before the
write stage, keeping outputs bit-identical to the streaming oracle.  The
legacy hand-rolled strip closure is gone.  The jitted SPMD program itself is
registered in the same cache under its geometry key, so repeated executors
on one pipeline reuse one program.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax>=0.8 exposes shard_map at top level
    shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.core.execplan import PlanCache
from repro.core.pipeline import Pipeline
from repro.core.splitting import padded_strip_rows, virtual_strip_regions
from repro.core.process_object import (
    ImageInfo,
    Mapper,
    ProcessObject,
    Reduction,
    Source,
    windowed_requests,
)
from repro.core.region import ImageRegion


class NotStripParallelizable(ValueError):
    """Raised when the graph violates the shard_map-mode requirements."""


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------
def halo_exchange_rows(
    x: jnp.ndarray, halo_top: int, halo_bot: int, axis_name: str, n: int
) -> jnp.ndarray:
    """Fetch ``halo_top`` rows from the device above and ``halo_bot`` rows
    from the device below via ``ppermute``; boundary devices edge-replicate
    their own first/last row (matches the streamed oracle's boundary_pad)."""
    if n == 1 or (halo_top == 0 and halo_bot == 0):
        pad = [(halo_top, halo_bot)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pad, mode="edge") if (halo_top or halo_bot) else x
    if halo_top > x.shape[0] or halo_bot > x.shape[0]:
        raise NotStripParallelizable(
            f"halo ({halo_top}/{halo_bot}) exceeds strip rows ({x.shape[0]}); "
            "use fewer workers or the streaming driver"
        )
    idx = lax.axis_index(axis_name)
    parts = []
    if halo_top:
        from_above = lax.ppermute(
            x[-halo_top:], axis_name, [(i, i + 1) for i in range(n - 1)]
        )
        edge = jnp.repeat(x[:1], halo_top, axis=0)
        parts.append(jnp.where(idx == 0, edge, from_above))
    parts.append(x)
    if halo_bot:
        from_below = lax.ppermute(
            x[:halo_bot], axis_name, [(i + 1, i) for i in range(n - 1)]
        )
        edge = jnp.repeat(x[-1:], halo_bot, axis=0)
        parts.append(jnp.where(idx == n - 1, edge, from_below))
    return jnp.concatenate(parts, axis=0)


# ---------------------------------------------------------------------------
# symbolic strip-plan extraction
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SourceStrip:
    source: Source
    pitch: int  # input rows per output strip (resolution scale × H)
    halo_top: int
    halo_bot: int


@dataclasses.dataclass
class StripPlan:
    """Everything needed to run the pipeline as one SPMD program."""

    n_workers: int
    strip_rows: int  # output rows per device (H)
    out_info: ImageInfo
    source_strips: List[SourceStrip]
    #: fn(local_arrays, axis_idx) -> (out_strip, {pname: state})
    fn: Callable
    #: always True since the virtual-padded-strip path retired the legacy
    #: hand-rolled closure: every strip body IS the shared canonical plan
    #: from the ExecutionPlan registry (kept as a field for introspection /
    #: back-compat with callers that asserted on it)
    unified: bool = True
    #: canonical signature of the shared per-strip plan
    plan_signature: Optional[Tuple] = None
    #: trailing virtual pad rows past the real image (cropped by the
    #: executor before the write stage; masked out of persistent state)
    pad_rows: int = 0
    #: registry key prefix for the jitted SPMD program (device ids appended
    #: by the executor)
    program_key: Tuple = ()


def _probe_edges(pipeline: Pipeline, mapper: Mapper, k: int, H: int, cols: int):
    """Unclamped requested-region propagation for worker ``k``'s strip, with
    the same window classification as the describe pass (``needs_origin``
    requests become static-shape bounding windows).  Returns a DFS-ordered
    list of (parent_or_None, node, region, in_window) — every
    producer→consumer edge occurrence plus the root."""
    infos = pipeline.update_information()
    edges = []

    def walk(parent, node: ProcessObject, region: ImageRegion, in_window: bool):
        edges.append((parent, node, region, in_window))
        ups = pipeline.inputs_of(node)
        if not ups:
            return
        in_infos = [infos[id(u)] for u in ups]
        reqs = node.requested_region(region, *in_infos)
        reqs, wbounds = windowed_requests(node, region.size, reqs, in_infos)
        for u, r, wb in zip(ups, reqs, wbounds):
            walk(node, u, r, in_window or wb is not None)

    walk(None, mapper, ImageRegion((k * H, 0), (H, cols)), False)
    return edges


def _unified_strip_fn(
    pipeline: Pipeline,
    mapper: Mapper,
    n_workers: int,
    cols: int,
    out_info: ImageInfo,
    strip_by_source: Dict[int, SourceStrip],
    plan_cache: PlanCache,
):
    """Build the per-strip body from the shared ExecutionPlan registry.

    Runs the *virtual* describe pass for every worker strip (host-side,
    cheap, against the row-padded geometry — so ragged last strips and n=2
    border strips describe like interior ones), requires every strip to
    share one canonical signature, and fetches/lowers the canonical closure
    through ``plan_cache`` so the SPMD program traces the *same* plan the
    streaming engine compiles for the equivalent stripes.  Per-worker
    ``needs_origin`` coordinates (covariant origins, windowed-read origins
    and persistent-mask row origins alike) become constant per-worker tables
    gathered at the mesh index; plan reads whose offsets are strip-invariant
    stay static slices of the halo-exchanged local shard, drifting window
    reads lower to ``lax.dynamic_slice`` at table offsets.

    Returns ``(strip_fn, description)``; raises
    :class:`NotStripParallelizable` when the geometry cannot share one
    interior trace (per-strip plan keys, mismatched walk shapes, reads
    outside the haloed window, unmaskable persistent state on a padded
    split).
    """
    persistent = pipeline.persistent_nodes()
    infos = pipeline.update_information()
    descs = [
        pipeline.describe_pull(mapper, strip, virtual=True)
        for strip in virtual_strip_regions(out_info.rows, cols, n_workers)
    ]
    kp = n_workers // 2
    d0 = descs[kp]
    if d0.pad_rows or any(d.pad_rows for d in descs):
        unmaskable = [p.name for p in d0.persistent_nodes if not p.supports_mask]
        if unmaskable:
            raise NotStripParallelizable(
                f"rows ({out_info.rows}) don't divide over {n_workers} "
                f"workers and persistent filter(s) {unmaskable} are not "
                "mask-aware (set supports_mask and handle `mask`); use the "
                "streaming driver or a worker count that divides the rows"
            )
    mismatched = [
        k for k in range(n_workers) if descs[k].signature != d0.signature
    ]
    if mismatched:
        raise NotStripParallelizable(
            f"worker strips {mismatched} do not share the canonical interior "
            "plan signature (per-strip plan keys — e.g. a resampling phase "
            "misaligned with the strip height — or non-uniform walk "
            "geometry); use the streaming driver or change the strip count"
        )
    nslots = len(d0.origin_values)
    if any(len(descs[k].origin_values) != nslots for k in range(n_workers)) or any(
        len(descs[k].reads) != len(d0.reads) for k in range(n_workers)
    ):
        raise NotStripParallelizable(
            "per-strip describe walks disagree in shape; use the streaming "
            "driver"
        )

    # per-slot origin tables over the mesh index: a constant gather handles
    # every per-strip drift the describe pass produced (affine or not)
    tables = [
        tuple(int(descs[k].origin_values[i]) for k in range(n_workers))
        for i in range(nslots)
    ]

    # every plan read is a window of the halo-exchanged shard: a static slice
    # when its offset is strip-invariant, a dynamic_slice at per-strip table
    # offsets otherwise (drifting windowed reads); windowed reads deliver the
    # full static window shape (row spill comes from halo edge-replication,
    # column spill from a uniform edge pad — the trace carries no pads)
    read_specs = []
    for i, (src, clamped, req) in enumerate(d0.reads):
        ss = strip_by_source.get(id(src))
        if ss is None or any(
            descs[k].reads[i][0] is not src for k in range(n_workers)
        ) or any(
            descs[k].reads[i][2].size != req.size for k in range(n_workers)
        ):
            raise NotStripParallelizable(
                f"{src.name}: per-strip reads disagree with the probe "
                "geometry; use the streaming driver"
            )
        local_rows = ss.pitch + ss.halo_top + ss.halo_bot
        src_cols = infos[id(src)].cols
        windowed = i < len(d0.windows) and d0.windows[i] is not None
        if windowed:
            rows, wcols = req.size
            offs = [
                descs[k].reads[i][2].row0 - (k * ss.pitch - ss.halo_top)
                for k in range(n_workers)
            ]
            cls = [descs[k].reads[i][2].col0 for k in range(n_workers)]
            if wcols <= src_cols:
                ncols, cpad = wcols, (0, 0)
                if any(c < 0 or c + wcols > src_cols for c in cls):
                    raise NotStripParallelizable(
                        f"{src.name}: a strip's read window leaves the image "
                        "columns; use the streaming driver"
                    )
            else:
                # window wider than the image: uniform right-edge pad
                # (window_request anchors every strip's window at col 0)
                ncols, cpad = src_cols, (0, wcols - src_cols)
                if any(c != 0 for c in cls):
                    raise NotStripParallelizable(
                        f"{src.name}: over-wide read windows must anchor at "
                        "column 0 on every strip; use the streaming driver"
                    )
        else:
            rows, ncols = clamped.rows, clamped.cols
            cpad = (0, 0)
            pl = clamped.col0 - req.col0  # col clamp baked in the trace
            offs = [
                descs[k].reads[i][2].row0 - (k * ss.pitch - ss.halo_top)
                for k in range(n_workers)
            ]
            cls = [descs[k].reads[i][2].col0 + pl for k in range(n_workers)]
        if any(o < 0 or o + rows > local_rows for o in offs):
            raise NotStripParallelizable(
                f"{src.name}: a strip's read spills outside the haloed local "
                f"shard ({local_rows} rows); use fewer workers or the "
                "streaming driver"
            )
        # static only when EVERY worker (border strips run this trace too,
        # via halo replication) agrees on the shard offset
        if all(offs[k] == offs[kp] and cls[k] == cls[kp]
               for k in range(n_workers)):
            read_specs.append((id(src), False, offs[kp], cls[kp], rows, ncols, cpad))
        else:
            if any(c < 0 or c + ncols > src_cols for c in cls):
                raise NotStripParallelizable(
                    f"{src.name}: drifting read columns leave the image; use "
                    "the streaming driver"
                )
            read_specs.append(
                (id(src), True, tuple(offs), tuple(cls), rows, ncols, cpad)
            )

    entry = plan_cache.compiled_for(d0, lambda: pipeline.lower_pull(d0))
    canonical = entry.canonical_fn

    def strip_fn(local_arrays: Dict[int, jnp.ndarray], axis_idx):
        arrays = []
        for sid, dyn_read, roff, coff, rows, ncols, cpad in read_specs:
            local = local_arrays[sid]
            if dyn_read:
                r = jnp.asarray(roff, jnp.int32)[axis_idx]
                c = jnp.asarray(coff, jnp.int32)[axis_idx]
                arr = lax.dynamic_slice(
                    local,
                    (r, c) + (0,) * (local.ndim - 2),
                    (rows, ncols) + tuple(local.shape[2:]),
                )
            else:
                arr = local[roff:roff + rows, coff:coff + ncols]
            if cpad != (0, 0):
                arr = jnp.pad(
                    arr, [(0, 0), cpad] + [(0, 0)] * (arr.ndim - 2),
                    mode="edge",
                )
            arrays.append(arr)
        origins = tuple(
            jnp.int32(t[0]) if len(set(t)) == 1
            else jnp.asarray(t, jnp.int32)[axis_idx]
            for t in tables
        )
        pstates = {p.name: p.reset() for p in persistent}
        return canonical(arrays, pstates, origins)

    return strip_fn, d0


def build_strip_plan(
    pipeline: Pipeline,
    mapper: Mapper,
    n_workers: int,
    axis_name: str = "workers",
    plan_cache: Optional[PlanCache] = None,
) -> StripPlan:
    infos = pipeline.update_information()
    out_info = infos[id(mapper)]
    H, pad_rows = padded_strip_rows(out_info.rows, n_workers)
    cols = out_info.cols

    # --- probe EVERY worker's strip (host-side, cheap) -----------------------
    probes = [_probe_edges(pipeline, mapper, k, H, cols) for k in range(n_workers)]
    if any(len(p) != len(probes[0]) for p in probes):
        raise NotStripParallelizable("graph shape varies per strip")

    #: per source: list of (pitch_or_None, [row ranges over all k])
    src_reads: Dict[int, List[Tuple[Optional[int], List[Tuple[int, int]]]]] = {}

    for i, (parent0, node0, r0, win0) in enumerate(probes[0]):
        occs = [p[i][2] for p in probes]
        if any(p[i][1] is not node0 for p in probes):
            raise NotStripParallelizable("graph traversal varies per strip")
        is_src = not pipeline.inputs_of(node0)
        row_ranges = [(r.row0, r.row1) for r in occs]
        if any(a.size != b.size for a, b in zip(occs, occs[1:])):
            raise NotStripParallelizable(
                f"{node0.name}: requested-region size varies per strip"
            )
        if win0:
            # window spec subtree: static shape by construction, origins may
            # drift freely (the unified path tables them per worker)
            if is_src:
                src_reads.setdefault(id(node0), []).append((None, row_ranges))
            continue
        # covariant edge: constant size, constant integer pitch, no col drift
        row_pitches = {b.row0 - a.row0 for a, b in zip(occs, occs[1:])}
        col_drifts = {b.col0 - a.col0 for a, b in zip(occs, occs[1:])}
        if len(row_pitches) > 1 or col_drifts - {0}:
            hint = (
                "; declare a window_bound on the requesting needs_origin "
                "filter to lower the drift to a windowed read"
                if parent0 is not None
                and getattr(parent0, "needs_origin", False)
                else ""
            )
            raise NotStripParallelizable(
                f"{node0.name}: requested regions are not translation-covariant "
                f"(row pitches {sorted(row_pitches)}, col drifts {sorted(col_drifts)})"
                f"{hint}"
            )
        pitch = row_pitches.pop() if row_pitches else 0  # 0 only when n_workers==1
        if is_src:
            if n_workers > 1 and pitch <= 0:
                raise NotStripParallelizable(f"{node0.name}: non-positive pitch {pitch}")
            src_reads.setdefault(id(node0), []).append((pitch, row_ranges))

    # --- per-source sharding pitch + combined halo over all reads/workers ----
    source_strips: List[SourceStrip] = []
    strip_by_source: Dict[int, SourceStrip] = {}
    for src in pipeline.sources():
        recs = src_reads.get(id(src))
        if not recs:
            continue
        cov_pitches = {p for p, _ in recs if p is not None}
        if len(cov_pitches) > 1:
            raise NotStripParallelizable(
                f"{src.name}: conflicting pitches across reads {sorted(cov_pitches)}"
            )
        if cov_pitches:
            pitch = cov_pitches.pop()
            if n_workers == 1:
                pitch = infos[id(src)].rows  # whole image on the single worker
        else:
            pitch = math.ceil(infos[id(src)].rows / n_workers)
        halo_top = halo_bot = 0
        for _, row_ranges in recs:
            for k, (a0, a1) in enumerate(row_ranges):
                halo_top = max(halo_top, k * pitch - a0)
                halo_bot = max(halo_bot, a1 - (k + 1) * pitch)
        ss = SourceStrip(src, pitch, max(0, halo_top), max(0, halo_bot))
        source_strips.append(ss)
        strip_by_source[id(src)] = ss

    geom = tuple(
        (ss.source._serial, ss.pitch, ss.halo_top, ss.halo_bot)
        for ss in source_strips
    )
    cache = plan_cache if plan_cache is not None else PlanCache()

    # --- the shared canonical plan from the ExecutionPlan layer --------------
    # (the only strip path: virtual padded strips make it total over ragged
    # splits and n=2 halos, so there is no legacy closure to fall back to)
    strip_fn, desc = _unified_strip_fn(
        pipeline, mapper, n_workers, cols, out_info, strip_by_source, cache,
    )
    return StripPlan(
        n_workers=n_workers,
        strip_rows=H,
        out_info=out_info,
        source_strips=source_strips,
        fn=strip_fn,
        unified=True,
        plan_signature=desc.signature,
        pad_rows=pad_rows,
        program_key=(
            "spmd", axis_name, n_workers, H, geom, desc.signature,
        ),
    )


# ---------------------------------------------------------------------------
# the distributed executor
# ---------------------------------------------------------------------------
def _combine_collective(red: Reduction, val, axis_name):
    if red.kind == "sum":
        return lax.psum(val, axis_name)
    if red.kind == "max":
        return lax.pmax(val, axis_name)
    if red.kind == "min":
        return lax.pmin(val, axis_name)
    if red.kind == "concat":
        return lax.all_gather(val, axis_name).reshape((-1,) + tuple(val.shape[1:]))
    raise ValueError(red.kind)


class ParallelExecutor:
    """Distribute one pipeline over a device mesh axis (paper §II.C.2)."""

    def __init__(
        self,
        pipeline: Pipeline,
        mapper: Mapper,
        devices: Optional[Sequence] = None,
        axis_name: str = "workers",
        plan_cache: Optional[PlanCache] = None,
    ):
        self.pipeline = pipeline
        self.mapper = mapper
        self.devices = list(devices if devices is not None else jax.devices())
        self.axis_name = axis_name
        self.n = len(self.devices)
        # the shared ExecutionPlan registry: pass the one the streaming
        # executor used and matching strip geometry becomes a registry hit
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.plan = build_strip_plan(
            pipeline, mapper, self.n, axis_name, plan_cache=self.plan_cache
        )
        self.mesh = Mesh(np.array(self.devices), (axis_name,))

    # -- global input staging --------------------------------------------------
    def _padded_global(self, ss: SourceStrip) -> np.ndarray:
        """Materialize a source and edge-pad its rows to n × pitch."""
        info = self.pipeline.info(ss.source)
        arr = np.asarray(ss.source.generate(info.full_region))
        if arr.ndim == 2:
            arr = arr[..., None]
        want = self.n * ss.pitch
        if want < arr.shape[0]:
            raise NotStripParallelizable(
                f"{ss.source.name}: pitch×workers ({want}) < image rows {arr.shape[0]}"
            )
        if want > arr.shape[0]:
            pad = want - arr.shape[0]
            arr = np.pad(arr, [(0, pad), (0, 0), (0, 0)], mode="edge")
        return arr

    def build_spmd(self):
        """Return (jitted SPMD callable, list of global input arrays)."""
        plan, axis, n = self.plan, self.axis_name, self.n
        ids = [id(ss.source) for ss in plan.source_strips]
        halos = {id(ss.source): (ss.halo_top, ss.halo_bot) for ss in plan.source_strips}
        persistent = self.pipeline.persistent_nodes()
        reds = {p.name: p.state_reductions for p in persistent}

        def worker(*shards):
            idx = lax.axis_index(axis)
            local = {}
            for sid, x in zip(ids, shards):
                ht, hb = halos[sid]
                local[sid] = halo_exchange_rows(x, ht, hb, axis, n)
            out, pstates = plan.fn(local, idx)
            agg = {
                name: {
                    k: _combine_collective(reds[name][k], v, axis)
                    for k, v in st.items()
                }
                for name, st in pstates.items()
            }
            return out, agg

        in_specs = tuple(P(axis, None, None) for _ in ids)
        out_specs = (P(axis, None, None), P())  # states fully reduced → replicated

        def make_program():
            # check_rep=False: shard_map has no replication rule for
            # pallas_call (the plan-layer Pallas fast path traces one into
            # the worker body).  The skipped check only guards the claim
            # that P() outputs are replicated — ours come from psum-style
            # collectives in _combine_collective, so it holds by
            # construction.
            fn = shard_map(
                worker, mesh=self.mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False,
            )
            return jax.jit(fn)

        # the jitted SPMD program lives in the shared registry too: a second
        # executor on the same pipeline/geometry/devices reuses one program
        key = self.plan.program_key + (tuple(d.id for d in self.devices),)
        jitted = self.plan_cache.get_or_build(key, make_program)
        globals_ = [self._padded_global(ss) for ss in plan.source_strips]
        return jitted, globals_

    def run(self, keep_outputs: bool = False):
        from repro.core.streaming import StreamResult  # cycle-free local import

        fn, globals_ = self.build_spmd()
        out, agg = fn(*globals_)
        out = np.asarray(out)[: self.plan.out_info.rows]  # crop row padding
        info = self.plan.out_info
        self.mapper.begin(info)
        outputs = []
        H = self.plan.strip_rows
        for w in range(self.n):
            r0, r1 = w * H, min((w + 1) * H, info.rows)
            if r0 >= r1:
                continue
            region = ImageRegion((r0, 0), (r1 - r0, info.cols))
            data = out[r0:r1]
            self.mapper.consume(region, data)
            if keep_outputs:
                outputs.append(data)
        presults = {
            p.name: p.synthesize(agg[p.name])
            for p in self.pipeline.persistent_nodes()
        }
        self.mapper.end()
        return StreamResult(
            regions_processed=self.n,
            pixels_processed=info.rows * info.cols,
            persistent_results=presults,
            outputs=outputs if keep_outputs else None,
            cache_stats=self.plan_cache.stats,
        )

    def lower(self):
        """Lower the SPMD program without running (dry-run path)."""
        fn, globals_ = self.build_spmd()
        args = [jax.ShapeDtypeStruct(g.shape, g.dtype) for g in globals_]
        with self.mesh:
            return fn.lower(*args)
