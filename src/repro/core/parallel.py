"""Cluster-parallel pipeline execution (paper §II.C) — TPU/JAX-native.

The paper runs one *pipeline replica per MPI process*, each producing a
different region of the output; persistent filters aggregate state with MPI
collectives.  Here the whole pipeline is traced once into a *local tile
function* and partitioned with ``shard_map`` over a 2-D device mesh:

  * the output domain is decomposed into an ``nr × nc`` grid of contiguous
    tiles (the paper's striped scheme is the ``nc = 1`` column of this grid,
    not a separate code path);
  * requested-region propagation is evaluated symbolically for *every*
    tile to derive, per source, the tile pitches (resolution scale per axis)
    and the row/column halos each device must fetch from its neighbors —
    the MPI point-to-point of the paper becomes ``lax.ppermute`` neighbor
    exchange along each mesh axis;
  * boundary devices edge-replicate their own rows/columns (ITK boundary
    condition), so the parallel result matches the streamed oracle — the
    paper's region-independence invariant (§II.C.1);
  * persistent filters accumulate per-device state which is combined with
    ``lax.psum`` / ``pmax`` / ``pmin`` / ``all_gather`` over both mesh axes
    (the paper's many-to-one / many-to-many MPI patterns), then
    ``synthesize`` runs once.

Two kinds of reads feed filters:

  * *covariant reads* — the request shifts by a constant integer pitch per
    tile row/column with constant size (box filters, integer-ratio
    resampling).  The planner slices the exact requested window from the
    haloed local shard; this is checked against the probes of all tiles.
  * *windowed reads* — requests of ``needs_origin`` filters (warps) whose
    exact windows drift fractionally per tile.  The describe pass lowers
    them to the plan layer's *window specs* (``ProcessObject.window_bound``):
    conservative static-shape bounding windows whose absolute origins are
    traced scalars.  Constant shape means one canonical plan for every
    interior tile; the per-tile window origin becomes a constant table
    gathered at the flat mesh index, and the window itself is a
    ``lax.dynamic_slice`` of the halo-exchanged local shard.

Anything else (data-dependent regions, non-affine request growth, drifting
``needs_origin`` reads without a ``window_bound``, per-tile plan keys, or a
``nc > 1`` grid over a pipeline whose column borders are not
virtualization-safe) raises ``NotTileParallelizable`` with diagnostics and
should run through the streaming driver (``NotStripParallelizable`` remains
as an alias).

**Unified ExecutionPlan path** — the *only* SPMD path.  ``build_tile_plan``
runs the cheap describe pass (``Pipeline.describe_pull``) for every tile of
the **virtual padded grid** (rows padded up to ``nr × Hr``, columns up to
``nc × Wc``; the ``"grid"`` describe walk never clamps in either axis), so
every tile — the ragged right/bottom edges of an uneven split and the border
tiles of small grids included — yields the *interior* plan signature.  All
tiles must share that one signature; the tile body is then fetched from the
shared :class:`~repro.core.execplan.PlanCache` — the very same registry (and
the very same lowered closure) the streaming engine uses.  A pipeline
streamed first and then run SPMD on any tile geometry is therefore a
registry *hit*: no new describe→lower pass, no new closure tree.  Per-tile
``needs_origin`` coordinates (covariant, window *and* persistent-mask
origins alike) are threaded as per-tile constant tables gathered at the flat
``(row, col)`` mesh index; plan reads are static slices of the
halo-exchanged local shard when their offsets are tile-invariant and
``lax.dynamic_slice`` windows otherwise.  Spill past the real image — halos
and virtual pad rows/columns — is materialized at the read stage
(edge-padded global + halo edge replication), never in the trace.
Masked-persistent accumulation is the only special case left, and it runs
through the same registry body: mask-aware filters accumulate under an
in-trace 2-D validity mask derived from their traced (row, col) origin, so
pad pixels never contaminate reduced state; the executor crops the pad
before the write stage, keeping outputs bit-identical to the streaming
oracle.  The jitted SPMD program itself is registered in the same cache
under its geometry key, so repeated executors on one pipeline reuse one
program.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax>=0.8 exposes shard_map at top level
    shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.core.execplan import PlanCache
from repro.core.pipeline import Pipeline
from repro.core.splitting import padded_tile_grid, virtual_tile_regions
from repro.core.process_object import (
    ImageInfo,
    Mapper,
    ProcessObject,
    Reduction,
    Source,
    windowed_requests,
)
from repro.core.region import ImageRegion


class NotTileParallelizable(ValueError):
    """Raised when the graph violates the shard_map tile-grid requirements
    (with diagnostics naming the offending node/axis/geometry)."""


#: back-compat alias — the 1-D strip path is the ``nc = 1`` column of the
#: tile grid, and its failure mode is the same exception
NotStripParallelizable = NotTileParallelizable


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------
def halo_exchange_rows(
    x: jnp.ndarray, halo_top: int, halo_bot: int, axis_name: str, n: int
) -> jnp.ndarray:
    """Fetch ``halo_top`` rows from the device above and ``halo_bot`` rows
    from the device below via ``ppermute``; boundary devices edge-replicate
    their own first/last row (matches the streamed oracle's boundary_pad)."""
    if n == 1 or (halo_top == 0 and halo_bot == 0):
        pad = [(halo_top, halo_bot)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pad, mode="edge") if (halo_top or halo_bot) else x
    if halo_top > x.shape[0] or halo_bot > x.shape[0]:
        raise NotTileParallelizable(
            f"halo ({halo_top}/{halo_bot}) exceeds strip rows ({x.shape[0]}); "
            "use fewer workers or the streaming driver"
        )
    idx = lax.axis_index(axis_name)
    parts = []
    if halo_top:
        from_above = lax.ppermute(
            x[-halo_top:], axis_name, [(i, i + 1) for i in range(n - 1)]
        )
        edge = jnp.repeat(x[:1], halo_top, axis=0)
        parts.append(jnp.where(idx == 0, edge, from_above))
    parts.append(x)
    if halo_bot:
        from_below = lax.ppermute(
            x[:halo_bot], axis_name, [(i + 1, i) for i in range(n - 1)]
        )
        edge = jnp.repeat(x[-1:], halo_bot, axis=0)
        parts.append(jnp.where(idx == n - 1, edge, from_below))
    return jnp.concatenate(parts, axis=0)


def halo_exchange_cols(
    x: jnp.ndarray, halo_left: int, halo_right: int, axis_name: str, n: int
) -> jnp.ndarray:
    """Column mirror of :func:`halo_exchange_rows`: fetch ``halo_left``
    columns from the device to the left and ``halo_right`` from the right
    via ``ppermute`` along the column mesh axis; boundary devices
    edge-replicate their own first/last column.  At ``n = 1`` this is a pure
    edge pad — exactly how the 1-D strip path materializes column spill."""
    if n == 1 or (halo_left == 0 and halo_right == 0):
        pad = [(0, 0), (halo_left, halo_right)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, pad, mode="edge") if (halo_left or halo_right) else x
    if halo_left > x.shape[1] or halo_right > x.shape[1]:
        raise NotTileParallelizable(
            f"halo ({halo_left}/{halo_right}) exceeds tile cols ({x.shape[1]}); "
            "use fewer column workers or the streaming driver"
        )
    idx = lax.axis_index(axis_name)
    parts = []
    if halo_left:
        from_left = lax.ppermute(
            x[:, -halo_left:], axis_name, [(i, i + 1) for i in range(n - 1)]
        )
        edge = jnp.repeat(x[:, :1], halo_left, axis=1)
        parts.append(jnp.where(idx == 0, edge, from_left))
    parts.append(x)
    if halo_right:
        from_right = lax.ppermute(
            x[:, :halo_right], axis_name, [(i + 1, i) for i in range(n - 1)]
        )
        edge = jnp.repeat(x[:, -1:], halo_right, axis=1)
        parts.append(jnp.where(idx == n - 1, edge, from_right))
    return jnp.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# symbolic tile-plan extraction
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SourceTile:
    source: Source
    pitch_r: int  # input rows per output tile row (resolution scale × Hr)
    pitch_c: int  # input cols per output tile col (resolution scale × Wc)
    halo_top: int
    halo_bot: int
    halo_left: int
    halo_right: int

    @property
    def pitch(self) -> int:  # back-compat: the 1-D strip path's row pitch
        return self.pitch_r


#: back-compat alias for the 1-D strip path's per-source record
SourceStrip = SourceTile


@dataclasses.dataclass
class TilePlan:
    """Everything needed to run the pipeline as one SPMD program over an
    ``nr × nc`` tile grid (1-D strip plans are the ``nc = 1`` column)."""

    grid: Tuple[int, int]  # (nr, nc)
    tile_rows: int  # output rows per device tile (Hr)
    tile_cols: int  # output cols per device tile (Wc)
    out_info: ImageInfo
    source_tiles: List[SourceTile]
    #: fn(local_arrays, flat_idx) -> (out_tile, {pname: state}); flat_idx is
    #: the row-major (row, col) mesh index ``ir * nc + ic``
    fn: Callable
    #: always True since the virtual-padded path retired the legacy
    #: hand-rolled closure: every tile body IS the shared canonical plan
    #: from the ExecutionPlan registry (kept as a field for introspection /
    #: back-compat with callers that asserted on it)
    unified: bool = True
    #: canonical signature of the shared per-tile plan
    plan_signature: Optional[Tuple] = None
    #: trailing virtual pad rows/cols past the real image (cropped by the
    #: executor before the write stage; masked out of persistent state)
    pad_rows: int = 0
    pad_cols: int = 0
    #: registry key prefix for the jitted SPMD program (device ids appended
    #: by the executor)
    program_key: Tuple = ()

    @property
    def n_workers(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def strip_rows(self) -> int:  # back-compat: 1-D strip height
        return self.tile_rows

    @property
    def source_strips(self) -> List[SourceTile]:  # back-compat
        return self.source_tiles


#: back-compat alias — a strip plan IS a tile plan with ``grid = (n, 1)``
StripPlan = TilePlan


def _probe_edges(pipeline: Pipeline, mapper: Mapper, tile: ImageRegion):
    """Unclamped requested-region propagation for one worker tile, with the
    same window classification as the describe pass (``needs_origin``
    requests become static-shape bounding windows).  Returns a DFS-ordered
    list of (parent_or_None, node, region, in_window) — every
    producer→consumer edge occurrence plus the root."""
    infos = pipeline.update_information()
    edges = []

    def walk(parent, node: ProcessObject, region: ImageRegion, in_window: bool):
        edges.append((parent, node, region, in_window))
        ups = pipeline.inputs_of(node)
        if not ups:
            return
        in_infos = [infos[id(u)] for u in ups]
        reqs = node.requested_region(region, *in_infos)
        reqs, wbounds = windowed_requests(node, region.size, reqs, in_infos)
        for u, r, wb in zip(ups, reqs, wbounds):
            walk(node, u, r, in_window or wb is not None)

    walk(None, mapper, tile, False)
    return edges


def _unified_tile_fn(
    pipeline: Pipeline,
    mapper: Mapper,
    grid: Tuple[int, int],
    out_info: ImageInfo,
    tile_by_source: Dict[int, SourceTile],
    plan_cache: PlanCache,
    describe_virtual: "bool | str",
):
    """Build the per-tile body from the shared ExecutionPlan registry.

    Runs the *virtual* describe pass for every tile of the grid (host-side,
    cheap, against the padded geometry — so ragged edge tiles and small-grid
    border tiles describe like interior ones), requires every tile to share
    one canonical signature, and fetches/lowers the canonical closure
    through ``plan_cache`` so the SPMD program traces the *same* plan the
    streaming engine compiles for the equivalent regions.  Per-tile
    ``needs_origin`` coordinates (covariant origins, windowed-read origins
    and persistent-mask origins alike) become constant per-tile tables
    gathered at the flat mesh index; plan reads whose offsets are
    tile-invariant stay static slices of the halo-exchanged local shard,
    drifting window reads lower to ``lax.dynamic_slice`` at table offsets.

    Returns ``(tile_fn, description)``; raises
    :class:`NotTileParallelizable` when the geometry cannot share one
    interior trace (per-tile plan keys, mismatched walk shapes, reads
    outside the haloed shard, unmaskable persistent state on a padded
    split).
    """
    nr, nc = grid
    n = nr * nc
    persistent = pipeline.persistent_nodes()
    descs = [
        pipeline.describe_pull(mapper, tile, virtual=describe_virtual)
        for tile in virtual_tile_regions(out_info.rows, out_info.cols, nr, nc)
    ]
    kp = (nr // 2) * nc + nc // 2  # a canonical interior tile
    d0 = descs[kp]
    if any(d.pad_rows or d.pad_cols for d in descs):
        unmaskable = [p.name for p in d0.persistent_nodes if not p.supports_mask]
        if unmaskable:
            raise NotTileParallelizable(
                f"image ({out_info.rows}×{out_info.cols}) doesn't divide over "
                f"the {nr}×{nc} grid and persistent filter(s) {unmaskable} "
                "are not mask-aware (set supports_mask and handle `mask`); "
                "use the streaming driver or a grid that divides the image"
            )
    mismatched = [k for k in range(n) if descs[k].signature != d0.signature]
    if mismatched:
        raise NotTileParallelizable(
            f"tiles {[(k // nc, k % nc) for k in mismatched]} of the "
            f"{nr}×{nc} grid do not share the canonical interior plan "
            "signature (per-tile plan keys — e.g. a resampling phase "
            "misaligned with the tile dimensions — or non-uniform walk "
            "geometry); use the streaming driver or change the grid"
        )
    nslots = len(d0.origin_values)
    if any(len(descs[k].origin_values) != nslots for k in range(n)) or any(
        len(descs[k].reads) != len(d0.reads) for k in range(n)
    ):
        raise NotTileParallelizable(
            "per-tile describe walks disagree in shape; use the streaming "
            "driver"
        )

    # per-slot origin tables over the flat mesh index: a constant gather
    # handles every per-tile drift the describe pass produced (affine or not)
    tables = [
        tuple(int(descs[k].origin_values[i]) for k in range(n))
        for i in range(nslots)
    ]

    # every plan read is a window of the halo-exchanged shard: a static slice
    # when its offset is tile-invariant, a dynamic_slice at per-tile table
    # offsets otherwise (drifting windowed reads); windowed reads deliver the
    # full static window shape (row/col spill comes from halo edge
    # replication — the trace carries no pads for them)
    read_specs = []
    for i, (src, clamped, req) in enumerate(d0.reads):
        ss = tile_by_source.get(id(src))
        if ss is None or any(
            descs[k].reads[i][0] is not src for k in range(n)
        ) or any(
            descs[k].reads[i][2].size != req.size for k in range(n)
        ):
            raise NotTileParallelizable(
                f"{src.name}: per-tile reads disagree with the probe "
                "geometry; use the streaming driver"
            )
        local_rows = ss.pitch_r + ss.halo_top + ss.halo_bot
        local_cols = ss.pitch_c + ss.halo_left + ss.halo_right
        windowed = i < len(d0.windows) and d0.windows[i] is not None
        # windowed reads deliver the full static window (reads[i][2]); exact
        # reads deliver the clamped rect (reads[i][1] — identical to the
        # request under "grid" describes, column-clamped under "rows")
        rows, ncols = (req.size if windowed else clamped.size)
        pick = 2 if windowed else 1
        roffs = [
            descs[k].reads[i][pick].row0
            - ((k // nc) * ss.pitch_r - ss.halo_top)
            for k in range(n)
        ]
        coffs = [
            descs[k].reads[i][pick].col0
            - ((k % nc) * ss.pitch_c - ss.halo_left)
            for k in range(n)
        ]
        if any(o < 0 or o + rows > local_rows for o in roffs) or any(
            c < 0 or c + ncols > local_cols for c in coffs
        ):
            raise NotTileParallelizable(
                f"{src.name}: a tile's read spills outside the haloed local "
                f"shard ({local_rows}×{local_cols}); use fewer workers or "
                "the streaming driver"
            )
        # static only when EVERY tile (border tiles run this trace too, via
        # halo replication) agrees on the shard offset
        if all(roffs[k] == roffs[kp] and coffs[k] == coffs[kp]
               for k in range(n)):
            read_specs.append((id(src), False, roffs[kp], coffs[kp], rows, ncols))
        else:
            read_specs.append(
                (id(src), True, tuple(roffs), tuple(coffs), rows, ncols)
            )

    entry = plan_cache.compiled_for(d0, lambda: pipeline.lower_pull(d0))
    canonical = entry.canonical_fn

    def tile_fn(local_arrays: Dict[int, jnp.ndarray], flat_idx):
        arrays = []
        for sid, dyn_read, roff, coff, rows, ncols in read_specs:
            local = local_arrays[sid]
            if dyn_read:
                r = jnp.asarray(roff, jnp.int32)[flat_idx]
                c = jnp.asarray(coff, jnp.int32)[flat_idx]
                arr = lax.dynamic_slice(
                    local,
                    (r, c) + (0,) * (local.ndim - 2),
                    (rows, ncols) + tuple(local.shape[2:]),
                )
            else:
                arr = local[roff:roff + rows, coff:coff + ncols]
            arrays.append(arr)
        origins = tuple(
            jnp.int32(t[0]) if len(set(t)) == 1
            else jnp.asarray(t, jnp.int32)[flat_idx]
            for t in tables
        )
        pstates = {p.name: p.reset() for p in persistent}
        return canonical(arrays, pstates, origins)

    return tile_fn, d0


def build_tile_plan(
    pipeline: Pipeline,
    mapper: Mapper,
    grid: Tuple[int, int],
    axis_name: str = "workers",
    plan_cache: Optional[PlanCache] = None,
) -> TilePlan:
    """Probe, validate and assemble the unified SPMD plan for an ``nr × nc``
    tile grid.  ``build_strip_plan`` is the ``(n, 1)`` wrapper."""
    nr, nc = grid
    if nr <= 0 or nc <= 0:
        raise ValueError(f"grid dims must be positive, got {grid}")
    n = nr * nc
    infos = pipeline.update_information()
    out_info = infos[id(mapper)]
    Hr, Wc, pad_rows, pad_cols = padded_tile_grid(
        out_info.rows, out_info.cols, nr, nc
    )
    tiles = virtual_tile_regions(out_info.rows, out_info.cols, nr, nc)

    # column sharding demands fully-virtual ("grid") describes; at nc == 1
    # a pipeline that only supports "rows" (or nothing) keeps the legacy
    # rows-only virtualization so strip behavior is unchanged
    mode = pipeline.virtual_describe_mode()
    if nc > 1 and mode != "grid":
        unmaskable = [
            p.name for p in pipeline.persistent_nodes() if not p.supports_mask
        ]
        if unmaskable:
            why = f"persistent filter(s) {unmaskable} are not mask-aware"
        elif not pipeline.virtual_rows_safe():
            why = (
                "row-border spill reaches an intermediate row-stencil filter "
                "(virtual_rows_safe() is False)"
            )
        else:
            why = (
                "column-border spill reaches an intermediate column-stencil "
                "filter (virtual_cols_safe() is False)"
            )
        raise NotTileParallelizable(
            f"a {nr}×{nc} tile grid needs fully-virtual ('grid') describes, "
            f"but {why}; use an (n, 1) strip grid or the streaming driver"
        )
    describe_virtual = mode if mode else "rows"

    # --- probe EVERY worker's tile (host-side, cheap) ------------------------
    probes = [_probe_edges(pipeline, mapper, tile) for tile in tiles]
    if any(len(p) != len(probes[0]) for p in probes):
        raise NotTileParallelizable("graph shape varies per tile")

    #: per source: list of (pitch_r_or_None, pitch_c_or_None,
    #: [(row0, row1)], [(col0, col1)]) over all tiles, flat row-major order
    src_reads: Dict[int, List[Tuple]] = {}

    for i, (parent0, node0, r0, win0) in enumerate(probes[0]):
        occs = [p[i][2] for p in probes]
        if any(p[i][1] is not node0 for p in probes):
            raise NotTileParallelizable("graph traversal varies per tile")
        is_src = not pipeline.inputs_of(node0)
        row_ranges = [(r.row0, r.row1) for r in occs]
        col_ranges = [(r.col0, r.col1) for r in occs]
        if any(r.size != occs[0].size for r in occs):
            raise NotTileParallelizable(
                f"{node0.name}: requested-region size varies per tile"
            )
        if win0:
            # window spec subtree: static shape by construction, origins may
            # drift freely (the unified path tables them per tile)
            if is_src:
                src_reads.setdefault(id(node0), []).append(
                    (None, None, row_ranges, col_ranges)
                )
            continue
        # covariant edge: constant size, a constant integer pitch per grid
        # axis, and no cross-axis drift (row origin independent of the tile
        # column and vice versa)
        pr = occs[nc].row0 - occs[0].row0 if nr > 1 else 0
        pc = occs[1].col0 - occs[0].col0 if nc > 1 else 0
        bad = [
            k for k in range(n)
            if occs[k].row0 != occs[0].row0 + (k // nc) * pr
            or occs[k].col0 != occs[0].col0 + (k % nc) * pc
        ]
        if bad:
            hint = (
                "; declare a window_bound on the requesting needs_origin "
                "filter to lower the drift to a windowed read"
                if parent0 is not None
                and getattr(parent0, "needs_origin", False)
                else ""
            )
            raise NotTileParallelizable(
                f"{node0.name}: requested regions are not translation-"
                f"covariant over the {nr}×{nc} grid (tiles "
                f"{[(k // nc, k % nc) for k in bad[:4]]} break the affine "
                f"row-pitch {pr} / col-pitch {pc} pattern){hint}"
            )
        if is_src:
            if nr > 1 and pr <= 0:
                raise NotTileParallelizable(
                    f"{node0.name}: non-positive row pitch {pr}"
                )
            if nc > 1 and pc <= 0:
                raise NotTileParallelizable(
                    f"{node0.name}: non-positive col pitch {pc}"
                )
            src_reads.setdefault(id(node0), []).append(
                (pr, pc, row_ranges, col_ranges)
            )

    # --- per-source sharding pitches + combined halos over all reads/tiles ---
    source_tiles: List[SourceTile] = []
    tile_by_source: Dict[int, SourceTile] = {}
    for src in pipeline.sources():
        recs = src_reads.get(id(src))
        if not recs:
            continue
        src_info = infos[id(src)]
        cov_pr = {pr for pr, _, _, _ in recs if pr is not None}
        cov_pc = {pc for _, pc, _, _ in recs if pc is not None}
        if len(cov_pr) > 1 or len(cov_pc) > 1:
            raise NotTileParallelizable(
                f"{src.name}: conflicting pitches across reads "
                f"(rows {sorted(cov_pr)}, cols {sorted(cov_pc)})"
            )
        if cov_pr:
            # a 1-device axis holds the whole extent (covariant pitch is 0
            # there — no second tile to difference against)
            pitch_r = src_info.rows if nr == 1 else cov_pr.pop()
            pitch_c = src_info.cols if nc == 1 else cov_pc.pop()
        else:
            pitch_r = math.ceil(src_info.rows / nr)
            pitch_c = math.ceil(src_info.cols / nc)
        halo_top = halo_bot = halo_left = halo_right = 0
        for _, _, row_ranges, col_ranges in recs:
            for k in range(n):
                ti, tj = k // nc, k % nc
                a0, a1 = row_ranges[k]
                c0, c1 = col_ranges[k]
                halo_top = max(halo_top, ti * pitch_r - a0)
                halo_bot = max(halo_bot, a1 - (ti + 1) * pitch_r)
                halo_left = max(halo_left, tj * pitch_c - c0)
                halo_right = max(halo_right, c1 - (tj + 1) * pitch_c)
        ss = SourceTile(
            src, pitch_r, pitch_c,
            max(0, halo_top), max(0, halo_bot),
            max(0, halo_left), max(0, halo_right),
        )
        source_tiles.append(ss)
        tile_by_source[id(src)] = ss

    geom = tuple(
        (ss.source._serial, ss.pitch_r, ss.pitch_c,
         ss.halo_top, ss.halo_bot, ss.halo_left, ss.halo_right)
        for ss in source_tiles
    )
    cache = plan_cache if plan_cache is not None else PlanCache()

    # --- the shared canonical plan from the ExecutionPlan layer --------------
    # (the only SPMD path: virtual padded tiles make it total over ragged
    # splits and small grids, so there is no legacy closure to fall back to)
    tile_fn, desc = _unified_tile_fn(
        pipeline, mapper, grid, out_info, tile_by_source, cache,
        describe_virtual,
    )
    return TilePlan(
        grid=grid,
        tile_rows=Hr,
        tile_cols=Wc,
        out_info=out_info,
        source_tiles=source_tiles,
        fn=tile_fn,
        unified=True,
        plan_signature=desc.signature,
        pad_rows=pad_rows,
        pad_cols=pad_cols,
        program_key=(
            "spmd", axis_name, nr, nc, Hr, Wc, geom, desc.signature,
        ),
    )


def build_strip_plan(
    pipeline: Pipeline,
    mapper: Mapper,
    n_workers: int,
    axis_name: str = "workers",
    plan_cache: Optional[PlanCache] = None,
) -> TilePlan:
    """The 1-D strip plan: exactly :func:`build_tile_plan` on the
    ``(n_workers, 1)`` grid."""
    return build_tile_plan(
        pipeline, mapper, (n_workers, 1), axis_name, plan_cache=plan_cache
    )


# ---------------------------------------------------------------------------
# the distributed executor
# ---------------------------------------------------------------------------
def _combine_collective(red: Reduction, val, axis_name):
    """``axis_name`` may be one mesh axis or a tuple of axes (the 2-D grid
    reduces over both at once)."""
    if red.kind == "sum":
        return lax.psum(val, axis_name)
    if red.kind == "max":
        return lax.pmax(val, axis_name)
    if red.kind == "min":
        return lax.pmin(val, axis_name)
    if red.kind == "concat":
        return lax.all_gather(val, axis_name).reshape((-1,) + tuple(val.shape[1:]))
    raise ValueError(red.kind)


class ParallelExecutor:
    """Distribute one pipeline over a 2-D device mesh (paper §II.C.2).

    ``grid=(nr, nc)`` lays ``nr × nc == len(devices)`` devices out as a tile
    grid; the default ``(n, 1)`` reproduces the 1-D strip decomposition."""

    def __init__(
        self,
        pipeline: Pipeline,
        mapper: Mapper,
        devices: Optional[Sequence] = None,
        axis_name: str = "workers",
        plan_cache: Optional[PlanCache] = None,
        grid: Optional[Tuple[int, int]] = None,
    ):
        self.pipeline = pipeline
        self.mapper = mapper
        self.devices = list(devices if devices is not None else jax.devices())
        self.axis_name = axis_name
        self.col_axis_name = axis_name + "_cols"
        self.n = len(self.devices)
        if grid is None:
            grid = (self.n, 1)
        nr, nc = grid
        if nr * nc != self.n:
            raise ValueError(
                f"grid {nr}×{nc} needs {nr * nc} devices, got {self.n}"
            )
        self.grid = (nr, nc)
        # the shared ExecutionPlan registry: pass the one the streaming
        # executor used and matching tile geometry becomes a registry hit
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.plan = build_tile_plan(
            pipeline, mapper, self.grid, axis_name, plan_cache=self.plan_cache
        )
        self.mesh = Mesh(
            np.array(self.devices).reshape(nr, nc),
            (axis_name, self.col_axis_name),
        )

    # -- global input staging --------------------------------------------------
    def _padded_global(self, ss: SourceTile) -> np.ndarray:
        """Materialize a source and edge-pad it to nr × pitch_r rows and
        nc × pitch_c cols."""
        nr, nc = self.grid
        info = self.pipeline.info(ss.source)
        arr = np.asarray(ss.source.generate(info.full_region))
        if arr.ndim == 2:
            arr = arr[..., None]
        want_r, want_c = nr * ss.pitch_r, nc * ss.pitch_c
        if want_r < arr.shape[0] or want_c < arr.shape[1]:
            raise NotTileParallelizable(
                f"{ss.source.name}: pitch×grid ({want_r}×{want_c}) < image "
                f"{arr.shape[0]}×{arr.shape[1]}"
            )
        pads = (want_r - arr.shape[0], want_c - arr.shape[1])
        if pads != (0, 0):
            arr = np.pad(
                arr, [(0, pads[0]), (0, pads[1]), (0, 0)], mode="edge"
            )
        return arr

    def build_spmd(self):
        """Return (jitted SPMD callable, list of global input arrays)."""
        plan = self.plan
        ar, ac = self.axis_name, self.col_axis_name
        nr, nc = self.grid
        ids = [id(ss.source) for ss in plan.source_tiles]
        halos = {
            id(ss.source): (ss.halo_top, ss.halo_bot, ss.halo_left, ss.halo_right)
            for ss in plan.source_tiles
        }
        persistent = self.pipeline.persistent_nodes()
        reds = {p.name: p.state_reductions for p in persistent}

        def worker(*shards):
            idx = lax.axis_index(ar) * nc + lax.axis_index(ac)
            local = {}
            for sid, x in zip(ids, shards):
                ht, hb, hl, hr = halos[sid]
                x = halo_exchange_rows(x, ht, hb, ar, nr)
                local[sid] = halo_exchange_cols(x, hl, hr, ac, nc)
            out, pstates = plan.fn(local, idx)
            agg = {
                name: {
                    k: _combine_collective(reds[name][k], v, (ar, ac))
                    for k, v in st.items()
                }
                for name, st in pstates.items()
            }
            return out, agg

        in_specs = tuple(P(ar, ac, None) for _ in ids)
        out_specs = (P(ar, ac, None), P())  # states fully reduced → replicated

        def make_program():
            # check_rep=False: shard_map has no replication rule for
            # pallas_call (the plan-layer Pallas fast path traces one into
            # the worker body).  The skipped check only guards the claim
            # that P() outputs are replicated — ours come from psum-style
            # collectives in _combine_collective, so it holds by
            # construction.
            fn = shard_map(
                worker, mesh=self.mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False,
            )
            return jax.jit(fn)

        # the jitted SPMD program lives in the shared registry too: a second
        # executor on the same pipeline/geometry/devices reuses one program
        key = self.plan.program_key + (tuple(d.id for d in self.devices),)
        jitted = self.plan_cache.get_or_build(key, make_program)
        globals_ = [self._padded_global(ss) for ss in plan.source_tiles]
        return jitted, globals_

    def run(self, keep_outputs: bool = False):
        from repro.core.streaming import StreamResult  # cycle-free local import

        fn, globals_ = self.build_spmd()
        out, agg = fn(*globals_)
        info = self.plan.out_info
        # crop the virtual row/col padding before the write stage
        out = np.asarray(out)[: info.rows, : info.cols]
        nr, nc = self.grid
        Hr, Wc = self.plan.tile_rows, self.plan.tile_cols
        self.mapper.begin(info)
        outputs = []
        for ti in range(nr):
            r0, r1 = ti * Hr, min((ti + 1) * Hr, info.rows)
            if r0 >= r1:
                continue
            for tj in range(nc):
                c0, c1 = tj * Wc, min((tj + 1) * Wc, info.cols)
                if c0 >= c1:
                    continue
                region = ImageRegion((r0, c0), (r1 - r0, c1 - c0))
                data = out[r0:r1, c0:c1]
                self.mapper.consume(region, data)
                if keep_outputs:
                    outputs.append(data)
        presults = {
            p.name: p.synthesize(agg[p.name])
            for p in self.pipeline.persistent_nodes()
        }
        self.mapper.end()
        return StreamResult(
            regions_processed=self.n,
            pixels_processed=info.rows * info.cols,
            persistent_results=presults,
            outputs=outputs if keep_outputs else None,
            cache_stats=self.plan_cache.stats,
        )

    def lower(self):
        """Lower the SPMD program without running (dry-run path)."""
        fn, globals_ = self.build_spmd()
        args = [jax.ShapeDtypeStruct(g.shape, g.dtype) for g in globals_]
        with self.mesh:
            return fn.lower(*args)
