"""Streaming engine (paper §II.B): pull the pipeline region by region.

The mapper picks a splitting strategy, then the engine processes regions on a
bounded memory footprint.  ``worker`` / ``n_workers`` select this worker's
slice of the schedule, so the same driver runs standalone or as one rank of a
host-level parallel run (e.g. one process per pod host feeding its devices).

Three layers make the hot loop run at hardware speed:

  1. **Canonical plans** — the describe pass (``Pipeline.describe_pull``)
     folds every shape/boundary-static quantity into a plan signature; the
     lower pass builds the closure threading absolute coordinates
     (``needs_origin``) and persistent-filter state through the pure function
     as traced arguments.  Drifting warp requests are classified as
     *windowed reads* (static-shape bounding windows, traced origins — see
     ``ProcessObject.window_bound``), so a striped warp run shares ONE
     signature across every stripe, borders included.
  2. **PlanCache** — the shared compiled-plan registry of the ExecutionPlan
     layer (:mod:`repro.core.execplan`), keyed by plan signature.  A uniform
     stripe split compiles exactly ONCE: border stripes describe against the
     virtual padded geometry (no row clamping — the halo spill is
     materialized by edge replication at the read stage, exactly like
     windowed reads and the SPMD prober), so top/interior/bottom all share
     the interior entry.  Pipelines whose persistent filters are not
     mask-aware, or whose halo requests land on intermediate filters
     (stacked neighborhood filters — see ``Pipeline.virtual_rows_safe``),
     keep exact clamped describes (one entry per border
     geometry).  Registry *hits* run the cheap describe pass only — the
     lower pass (closure construction) happens on misses.
     Hit/miss/compile/lower/eviction counts are surfaced in
     ``StreamResult.cache_stats``; the same registry serves the SPMD
     :class:`~repro.core.parallel.ParallelExecutor`, whose virtual padded
     strips land on the very same interior entries (the shared read stage,
     :func:`~repro.core.execplan.read_plan_sources`, clamps + edge-pads any
     virtual row spill host-side, mirroring the SPMD halo replication), so
     streaming→SPMD stays a registry hit on ragged and n=2 splits too.
  3. **Async double buffering** — with ``prefetch=k``, source reads for the
     next ``k`` regions run on a thread pool while the device computes the
     current one, and ``mapper.consume`` is handed to a background writer
     behind a bounded queue.  Windowed reads prefetch the full static-shape
     window (edge-replicating any border spill host-side), so the hot loop
     feeds fixed-shape buffers to one compiled function.  In-flight memory
     stays bounded at roughly ``2·prefetch + 2`` region buffers (k
     read-ahead + one computing + k + 1 queued writes), preserving the
     paper's memory-budget guarantee with a constant factor.

Pipelines containing :class:`PersistentFilter` nodes run through the compiled
path too: state is carried across regions as
``fn(arrays, pstates, origins) -> (pixels, new_pstates)``.

The seed semantics stay reachable for A/B: ``use_jit=False`` is the eager
pull, and ``cache=False`` restores the per-region re-jit behavior.

``run_pool`` is the single-host concurrent driver: ``n_workers`` threads
drain one shared :class:`~repro.core.scheduling.WorkStealingQueue` (or their
static/LPT slices) against a shared :class:`PlanCache` — the dynamic load
balancing the paper names as future work (§IV.C).
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execplan import (  # noqa: F401 — re-exported for back-compat
    CacheStats,
    PlanCache,
    _CompiledEntry,
)
from repro.core.pipeline import Pipeline
from repro.core.process_object import Mapper, PersistentFilter
from repro.core.region import ImageRegion
from repro.core.scheduling import (
    FifoQueue,
    WorkStealingQueue,
    lpt_schedule,
    static_schedule,
    work_stealing_schedule,
)
from repro.core.splitting import Splitter, StripeSplitter

_SCHEDULERS = ("static", "lpt", "work_stealing")


def _virtual_describe_mode(pipeline: Pipeline) -> "bool | str":
    """The virtual describe mode the streaming drivers use for every strip
    or tile: ``"grid"`` (no clamping in either axis), ``"rows"`` (rows only)
    or ``False`` (exact clamped describes).  Structural conditions, decided
    by :meth:`Pipeline.virtual_describe_mode`:

      * any persistent filter must be mask-aware — under virtual geometry a
        border region's accumulation can include edge-replicated pad pixels
        that only a validity mask (``supports_mask``) keeps out of the
        reduction;
      * every spilling halo request on the virtualized axis must land
        directly on a source (:meth:`Pipeline.virtual_rows_safe` /
        :meth:`Pipeline.virtual_cols_safe`) — a halo landing on an
        intermediate filter (stacked neighborhood filters) is clamped and
        output-replicated by the exact walk but *computed* from replicated
        source rows by the virtual walk, so those pipelines keep the exact
        per-border describes to preserve the eager oracle's border pixels.

    The SPMD tile prober (:func:`repro.core.parallel.build_tile_plan`) takes
    its mode from the same method, so a streaming warm-up and a subsequent
    grid run land on one registry entry."""
    return pipeline.virtual_describe_mode()


class _WriteBehind:
    """Hands ``consume`` to a background thread through a bounded queue (the
    write-behind half of the double buffer).  On a consume error the thread
    keeps draining so producers never deadlock; the error re-raises on the
    producer side at the next ``put`` or at ``close``."""

    _STOP = object()

    def __init__(self, consume: Callable[[ImageRegion, np.ndarray], None], depth: int):
        self._consume = consume
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._loop, name="write-behind", daemon=True
        )
        self._thread.start()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is self._STOP:
                return
            if self._error is not None:
                continue  # drain without consuming
            try:
                self._consume(*item)
            except BaseException as e:  # noqa: BLE001 — must cross threads
                self._error = e

    def put(self, region: ImageRegion, data: np.ndarray) -> None:
        if self._error is not None:
            raise self._error
        self._q.put((region, data))

    def close(self) -> None:
        self._q.put(self._STOP)
        self._thread.join()
        if self._error is not None:
            raise self._error


@dataclasses.dataclass
class StreamResult:
    regions_processed: int
    pixels_processed: int
    persistent_results: Dict[str, Dict[str, jnp.ndarray]]
    #: per-region pixel outputs, only kept when ``keep_outputs=True``
    outputs: Optional[List[np.ndarray]] = None
    #: plan-cache counters for this run (None on the eager / re-jit paths).
    #: This is the LIVE CacheStats object — it keeps counting after the run
    #: (documented behavior, see ``reset_global_plan_cache``).
    cache_stats: Optional[CacheStats] = None
    #: the same counters frozen at run end as a plain dict
    #: (``PlanCache.stats_snapshot()``) — what metrics/benchmarks should
    #: read instead of reaching into the live counters
    cache_snapshot: Optional[Dict[str, int]] = None


class StreamingExecutor:
    def __init__(
        self,
        pipeline: Pipeline,
        mapper: Mapper,
        splitter: Optional[Splitter] = None,
        worker: int = 0,
        n_workers: int = 1,
        scheduler: str = "static",
        cost_fn: Optional[Callable[[ImageRegion], float]] = None,
        use_jit: bool = True,
        cache: bool = True,
        plan_cache: Optional[PlanCache] = None,
        prefetch: int = 2,
        max_cached_plans: Optional[int] = None,
        region_gate=None,
    ):
        if scheduler not in _SCHEDULERS:
            raise ValueError(scheduler)
        self.pipeline = pipeline
        self.mapper = mapper
        self.splitter = splitter or StripeSplitter(n_splits=max(1, n_workers) * 4)
        self.worker = worker
        self.n_workers = n_workers
        self.scheduler = scheduler
        self.cost_fn = cost_fn or (lambda r: float(r.num_pixels))
        self.use_jit = use_jit
        self.cache = cache
        # explicit None check: an empty PlanCache is falsy (it has __len__)
        self.plan_cache = (
            plan_cache if plan_cache is not None else PlanCache(max_cached_plans)
        )
        self.prefetch = max(0, int(prefetch))
        # region-availability gate (pipelined stage DAGs): wait(desc) blocks
        # until the rows the region reads are committed upstream; done(desc)
        # releases them once the region's output has been handed off
        self.region_gate = region_gate
        # Border strips describe against the virtual padded geometry (like the
        # SPMD prober), so a striped halo run shares ONE interior signature:
        # the row spill of border halos is materialized at the read stage
        # instead of being clamped into a per-border plan.  Persistent filters
        # that are not mask-aware would accumulate the replicated pad rows, so
        # those pipelines keep the exact clamped describes.
        self.describe_virtual = _virtual_describe_mode(pipeline)

    def my_regions(self) -> List[ImageRegion]:
        info = self.pipeline.info(self.mapper)
        regions = self.splitter.split(info.full_region, info)
        if self.scheduler == "static":
            sched = static_schedule(regions, self.n_workers)
        elif self.scheduler == "lpt":
            sched = lpt_schedule(regions, self.n_workers, self.cost_fn)
        else:
            sched = work_stealing_schedule(regions, self.n_workers, self.cost_fn)
        return [regions[i] for i in sched[self.worker]]

    # -- the prefetch stage: host-side planning + source reads ----------------
    def _prepare(self, region: ImageRegion):
        # describe pass only; the O(graph) closure tree is lowered by the
        # registry on misses — cache hits never rebuild it.  Virtual geometry
        # (when safe) folds border strips onto the interior signature.
        desc = self.pipeline.describe_pull(
            self.mapper, region, virtual=self.describe_virtual
        )
        if self.region_gate is not None:
            # block (on the prefetch thread) until the input rows this region
            # actually reads are committed by the upstream stage
            self.region_gate.wait(desc)
        fn = self.plan_cache.compiled_for(
            desc, lambda: self.pipeline.lower_pull(desc)
        )
        arrays = desc.read_sources()
        return desc, fn, arrays

    def run(self, keep_outputs: bool = False) -> StreamResult:
        pipeline, mapper = self.pipeline, self.mapper
        info = pipeline.info(mapper)
        mapper.begin(info)

        # persistent-filter state lives across regions (paper's Reset)
        pstates = {p.name: p.reset() for p in pipeline.persistent_nodes()}

        def hook(node: PersistentFilter, region: ImageRegion, inputs):
            pstates[node.name] = node.accumulate(pstates[node.name], region, *inputs)

        outputs: List[np.ndarray] = []
        pixels = 0
        regions = self.my_regions()
        compiled_path = self.use_jit and self.cache

        # hand the region schedule to range-readable sources before the
        # region loop: tiled/remote sources (RasterSource.read_ahead)
        # prefetch the covering tiles on their own thread, overlapping range
        # fetches with plan execution.  A best-effort hint — sources clamp
        # the schedule to their own geometry and plain sources ignore it.
        for src in pipeline.sources():
            ra = getattr(src, "read_ahead", None)
            if callable(ra):
                ra(regions)

        def compute(prep) -> np.ndarray:
            nonlocal pstates
            plan, fn, arrays = prep
            out, pstates = fn(arrays, pstates, plan.origins())
            if self.region_gate is not None:
                # pacing-only release (the data lives on disk): fire once the
                # region's pixels are produced and handed to the write stage
                self.region_gate.done(plan)
            return np.asarray(out)

        def produce_sync(region: ImageRegion) -> np.ndarray:
            if compiled_path:
                return compute(self._prepare(region))
            if self.region_gate is not None:
                # non-compiled paths still gate on the described reads (the
                # gate clamps virtual row spill to the committed extent)
                desc = pipeline.describe_pull(
                    mapper, region, virtual=self.describe_virtual
                )
                self.region_gate.wait(desc)
                self.region_gate.done(desc)
            if self.use_jit and not pipeline.persistent_nodes():
                # cache=False A/B baseline: the seed's per-region re-jit
                plan = pipeline.compile_pull(mapper, region)
                return np.asarray(jax.jit(plan.fn)(plan.read_sources()))
            # eager pull; the hook observes every region exactly once
            return np.asarray(pipeline.pull(mapper, region, persistent_hook=hook))

        try:
            if compiled_path and self.prefetch > 0 and len(regions) > 1:
                pixels = self._run_async(regions, compute, outputs, keep_outputs)
            else:
                for region in regions:
                    data = produce_sync(region)
                    mapper.consume(region, data)
                    pixels += region.num_pixels
                    if keep_outputs:
                        outputs.append(data)
        except BaseException:
            try:
                mapper.end()  # release writer descriptors on the error path
            except Exception:
                pass
            raise

        # paper's Synthesis: finalize persistent state after the region loop
        presults = {
            p.name: p.synthesize(pstates[p.name]) for p in pipeline.persistent_nodes()
        }
        mapper.end()
        return StreamResult(
            regions_processed=len(regions),
            pixels_processed=pixels,
            persistent_results=presults,
            outputs=outputs if keep_outputs else None,
            cache_stats=self.plan_cache.stats if compiled_path else None,
            cache_snapshot=(
                self.plan_cache.stats_snapshot() if compiled_path else None
            ),
        )

    def _run_async(self, regions, compute, outputs, keep_outputs) -> int:
        """Double-buffered loop: reads for region i+1..i+prefetch overlap the
        device computing region i; writes trail behind on their own thread."""
        depth = self.prefetch
        pixels = 0
        writer = _WriteBehind(self.mapper.consume, depth + 1)
        pending: "collections.deque" = collections.deque()
        nxt = 0
        error: Optional[BaseException] = None
        with ThreadPoolExecutor(
            max_workers=depth, thread_name_prefix="prefetch"
        ) as pool:

            def fill():
                nonlocal nxt
                while nxt < len(regions) and len(pending) < depth:
                    pending.append(
                        (regions[nxt], pool.submit(self._prepare, regions[nxt]))
                    )
                    nxt += 1

            try:
                fill()
                while pending:
                    region, fut = pending.popleft()
                    prep = fut.result()
                    fill()  # keep the read window full while we compute
                    data = compute(prep)
                    pixels += region.num_pixels
                    if keep_outputs:
                        outputs.append(data)
                    writer.put(region, data)
            except BaseException as e:  # noqa: BLE001 — re-raised below
                error = e
            finally:
                for _, fut in pending:
                    fut.cancel()
                try:
                    writer.close()
                except BaseException as e:  # noqa: BLE001
                    if error is None:
                        error = e
        if error is not None:
            raise error
        return pixels


def run_pool(
    pipeline: Pipeline,
    mapper: Mapper,
    splitter: Optional[Splitter] = None,
    *,
    n_workers: int = 1,
    scheduler: str = "work_stealing",
    cost_fn: Optional[Callable[[ImageRegion], float]] = None,
    use_jit: bool = True,
    plan_cache: Optional[PlanCache] = None,
    keep_outputs: bool = False,
    region_gate=None,
    in_order: bool = False,
) -> StreamResult:
    """Run one pipeline with ``n_workers`` concurrent threads on this host.

    With ``scheduler="work_stealing"`` the workers drain one shared
    :class:`WorkStealingQueue` (idle workers steal from the most-loaded
    victim's tail); ``"static"`` / ``"lpt"`` give each worker its precomputed
    slice but still run the slices concurrently.  All workers share one
    :class:`PlanCache`, so a uniform split still compiles once.  Per-worker
    persistent states are combined with the filters' reductions, then
    synthesized once — the thread-level analogue of the paper's MPI
    many-to-one Synthesis.

    ``region_gate`` (pipelined stage DAGs, :mod:`repro.core.dag`) makes the
    workers block *per region*: each region's describe pass runs first, the
    gate waits until the input rows it reads are committed upstream, and the
    gate releases them after the region's output is consumed.  Gated runs
    hand regions out in strict region order (:class:`FifoQueue`) regardless
    of ``scheduler`` — readiness follows the producer's commit frontier, so
    in-order hand-out keeps every worker on ready (or soonest-ready) regions
    and the per-edge in-flight window bounded.  ``in_order=True`` forces the
    same FIFO hand-out on an *ungated* run: the pipelined orchestrator sets
    it on producer stages so strips are offered downstream in the consumers'
    row order and backpressure tracks the real commit frontier instead of a
    work-stealing shuffle."""
    if scheduler not in _SCHEDULERS:
        raise ValueError(scheduler)
    n_workers = max(1, int(n_workers))
    info = pipeline.info(mapper)  # also primes the metadata cache (thread-shared)
    splitter = splitter or StripeSplitter(n_splits=n_workers * 4)
    regions = splitter.split(info.full_region, info)
    cost = cost_fn or (lambda r: float(r.num_pixels))
    cache = plan_cache if plan_cache is not None else PlanCache()

    mapper.begin(info)
    consume_lock = (
        None if getattr(mapper, "thread_safe", False) else threading.Lock()
    )

    def consume(region, data):
        if consume_lock is None:
            mapper.consume(region, data)
        else:
            with consume_lock:
                mapper.consume(region, data)

    persistent = pipeline.persistent_nodes()
    # same border-strip virtualization as StreamingExecutor._prepare: all
    # workers then land on the one interior signature (single lower+compile)
    describe_virtual = _virtual_describe_mode(pipeline)
    worker_states = [{p.name: p.reset() for p in persistent} for _ in range(n_workers)]
    counts = [0] * n_workers
    pixel_counts = [0] * n_workers
    outputs_by_index: Optional[Dict[int, np.ndarray]] = {} if keep_outputs else None

    if region_gate is not None or in_order:
        fifo = FifoQueue(len(regions))

        def indices(w):
            while True:
                i = fifo.take(w)
                if i is None:
                    return
                yield i

    elif scheduler == "work_stealing":
        wsq = WorkStealingQueue(
            len(regions), n_workers, costs=[cost(r) for r in regions]
        )

        def indices(w):
            while True:
                i = wsq.take(w)
                if i is None:
                    return
                yield i

    else:
        sched = (
            static_schedule(regions, n_workers)
            if scheduler == "static"
            else lpt_schedule(regions, n_workers, cost)
        )

        def indices(w):
            return iter(sched[w])

    def work(w: int) -> None:
        pstates = worker_states[w]

        def hook(node, reg, inputs):
            pstates[node.name] = node.accumulate(pstates[node.name], reg, *inputs)

        for i in indices(w):
            region = regions[i]
            desc = None
            if use_jit or region_gate is not None:
                desc = pipeline.describe_pull(
                    mapper, region, virtual=describe_virtual
                )
                if region_gate is not None:
                    region_gate.wait(desc)  # block until input rows commit
            if use_jit:
                fn = cache.compiled_for(desc, lambda: pipeline.lower_pull(desc))
                out, pstates = fn(desc.read_sources(), pstates, desc.origins())
                data = np.asarray(out)
            else:
                data = np.asarray(
                    pipeline.pull(mapper, region, persistent_hook=hook)
                )
            consume(region, data)
            if region_gate is not None:
                region_gate.done(desc)  # region consumed: release input rows
            counts[w] += 1
            pixel_counts[w] += region.num_pixels
            if outputs_by_index is not None:
                outputs_by_index[i] = data
        worker_states[w] = pstates

    try:
        if n_workers == 1:
            work(0)
        else:
            with ThreadPoolExecutor(
                max_workers=n_workers, thread_name_prefix="pool"
            ) as pool:
                futs = [pool.submit(work, w) for w in range(n_workers)]
                for f in futs:
                    f.result()
    except BaseException:
        try:
            mapper.end()  # release writer descriptors on the error path
        except Exception:
            pass
        raise

    combined = {p.name: worker_states[0][p.name] for p in persistent}
    for states in worker_states[1:]:
        for p in persistent:
            combined[p.name] = p.combine_states(combined[p.name], states[p.name])
    presults = {p.name: p.synthesize(combined[p.name]) for p in persistent}
    mapper.end()
    return StreamResult(
        regions_processed=sum(counts),
        pixels_processed=sum(pixel_counts),
        persistent_results=presults,
        outputs=(
            [outputs_by_index[i] for i in sorted(outputs_by_index)]
            if outputs_by_index is not None
            else None
        ),
        cache_stats=cache.stats if use_jit else None,
        cache_snapshot=cache.stats_snapshot() if use_jit else None,
    )


class BatchedRegionPuller:
    """Signature-batched region pulls: the serving engine's entry point into
    the ExecutionPlan layer.

    A batch of requested regions is described (cheap, per region), grouped by
    canonical plan signature — the :class:`PlanCache` key IS the batch key —
    and each group executes as **one** invocation of a ``jax.vmap``-batched
    build of the group's compiled plan: source arrays and origin scalars
    stack along a leading tile axis, so N same-signature tiles cost one XLA
    dispatch instead of N.  Batched programs register in the same
    :class:`PlanCache` under ``("serve_batched", signature, bucket)``; batch
    sizes round up to the configured buckets (padding replicates the last
    tile) so the registry holds a bounded number of batched traces per
    signature.  Outputs are bit-identical to the unbatched per-tile path —
    the serving-diff CI job locks this in.

    Pipelines with persistent filters are refused: a persistent reduction
    makes tile outputs depend on request order, which serving cannot honor.

    ``virtual`` should carry the same describe mode the streaming oracle
    would pick (:func:`_virtual_describe_mode`), so tile signatures collapse
    onto the entries a streaming warm-up run already lowered.

    ``read_cache_entries`` bounds an LRU of per-region source reads (the
    raster block cache of a tile server: hot Zipf tiles re-request the same
    windows, and the host-side read is the per-tile cost batching cannot
    amortize).  Cached reads are the *same arrays* the uncached path would
    produce, so outputs are unaffected; 0 disables.
    """

    def __init__(
        self,
        pipeline: Pipeline,
        node,
        plan_cache: Optional[PlanCache] = None,
        batch_sizes=(1, 4, 16),
        virtual: "Optional[bool | str]" = None,
        read_cache_entries: int = 1024,
    ):
        if pipeline.persistent_nodes():
            raise ValueError(
                "BatchedRegionPuller: pipeline has persistent filters "
                f"({[p.name for p in pipeline.persistent_nodes()]}) — "
                "per-tile serving cannot thread cross-region state"
            )
        self.pipeline = pipeline
        self.node = node
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
        if not self.batch_sizes or self.batch_sizes[0] < 1:
            raise ValueError(f"bad batch_sizes: {batch_sizes}")
        self.virtual = (
            _virtual_describe_mode(pipeline) if virtual is None else virtual
        )
        self.read_cache_entries = int(read_cache_entries)
        self._read_cache: "collections.OrderedDict[Tuple, List]" = (
            collections.OrderedDict()
        )
        self._read_lock = threading.Lock()
        self.read_hits = 0
        self.read_misses = 0

    def _read(self, desc) -> List:
        """``desc.read_sources()`` through the bounded read LRU.  The key is
        the described output region + signature — for a fixed (pipeline,
        node, describe mode) that pins the exact read windows."""
        if self.read_cache_entries <= 0:
            return desc.read_sources()
        key = (desc.out_region.index, desc.out_region.size, desc.signature)
        with self._read_lock:
            arrays = self._read_cache.get(key)
            if arrays is not None:
                self._read_cache.move_to_end(key)
                self.read_hits += 1
                return arrays
        self.read_misses += 1
        arrays = desc.read_sources()
        with self._read_lock:
            self._read_cache[key] = arrays
            self._read_cache.move_to_end(key)
            while len(self._read_cache) > self.read_cache_entries:
                self._read_cache.popitem(last=False)
        return arrays

    def describe(self, region: ImageRegion):
        return self.pipeline.describe_pull(
            self.node, region, virtual=self.virtual
        )

    def _entry(self, desc) -> _CompiledEntry:
        return self.plan_cache.compiled_for(
            desc, lambda: self.pipeline.lower_pull(desc)
        )

    def bucket(self, n: int) -> int:
        """Smallest configured batch bucket holding ``n`` tiles (the largest
        bucket when ``n`` exceeds them all — callers split oversize groups)."""
        for b in self.batch_sizes:
            if n <= b:
                return b
        return self.batch_sizes[-1]

    def _batched_program(self, desc, bucket: int):
        """The jitted vmap of this signature's canonical closure, from the
        shared registry.  Mirrors ``_CompiledEntry``'s trace counting: the
        wrapper bumps ``stats.compiles`` at trace time only, so a warm
        registry proves itself with a zero compile delta."""
        entry = self._entry(desc)
        stats = self.plan_cache.stats

        def build():
            def counted(arrays, pstates, origins):
                stats.compiles += 1  # executes at trace time only
                return entry.canonical_fn(arrays, pstates, origins)

            return jax.jit(jax.vmap(counted, in_axes=(0, None, 0)))

        return self.plan_cache.get_or_build(
            ("serve_batched", desc.signature, bucket), build
        )

    def pull_one(self, region: ImageRegion) -> np.ndarray:
        """Unbatched single-region pull through the registry (the per-tile
        oracle the serving-diff compares the batched path against)."""
        desc = self.describe(region)
        out, _ = self._entry(desc)(self._read(desc), {}, desc.origins())
        return np.asarray(out)

    def _chunks(self, n: int) -> List[int]:
        """Decompose a group of ``n`` tiles into bucket-sized chunks, peeling
        exact smaller buckets off when padding to the next bucket would waste
        more than half the real work (8 tiles on buckets (1,4,16) runs as
        4+4, not padded to 16)."""
        out: List[int] = []
        while n > 0:
            b = self.bucket(n)
            if b <= n:
                take = b
            else:
                lower = max(x for x in self.batch_sizes if x <= n)
                if lower > 1 and (b - n) * 2 >= n:
                    take = lower
                else:
                    out.append(n)  # pad n up to b in a single call
                    break
            out.append(take)
            n -= take
        return out

    def pull_described(self, descs) -> List[np.ndarray]:
        """Execute already-described same-signature requests as one batched
        invocation (singletons skip the vmap program and run unbatched).
        Groups that don't land on a bucket split into bucket-exact chunks
        (see :meth:`_chunks`); only the final remainder pads."""
        if not descs:
            return []
        if len(descs) == 1:
            d = descs[0]
            out, _ = self._entry(d)(self._read(d), {}, d.origins())
            return [np.asarray(out)]
        sizes = self._chunks(len(descs))
        if len(sizes) > 1:
            out: List[np.ndarray] = []
            i = 0
            for s in sizes:
                out.extend(self.pull_described(descs[i : i + s]))
                i += s
            return out
        n = len(descs)
        bucket = self.bucket(n)
        arrays = [self._read(d) for d in descs]
        origins = [d.origins() for d in descs]
        while len(arrays) < bucket:  # pad by replicating the last tile
            arrays.append(arrays[-1])
            origins.append(origins[-1])
        stacked = [
            jnp.stack([a[k] for a in arrays]) for k in range(len(arrays[0]))
        ]
        ovecs = tuple(
            jnp.asarray([o[s] for o in origins], dtype=jnp.int32)
            for s in range(len(origins[0]))
        )
        fn = self._batched_program(descs[0], bucket)
        out, _ = fn(stacked, {}, ovecs)
        out = np.asarray(out)
        return [out[i] for i in range(n)]

    def pull_many(self, regions) -> List[np.ndarray]:
        """Pull a batch of regions, coalescing same-signature requests into
        one batched invocation each.  Output order matches input order."""
        descs = [self.describe(r) for r in regions]
        groups: Dict[Tuple, List[int]] = {}
        for i, d in enumerate(descs):
            groups.setdefault(d.signature, []).append(i)
        out: List[Optional[np.ndarray]] = [None] * len(regions)
        for idxs in groups.values():
            tiles = self.pull_described([descs[i] for i in idxs])
            for i, tile in zip(idxs, tiles):
                out[i] = tile
        return out  # type: ignore[return-value]

    def warm(self, regions, buckets=None) -> Dict[str, int]:
        """Serving warm-up: lower + compile every distinct signature in
        ``regions`` (executed once, via :meth:`PlanCache.warm`) and prime the
        vmap-batched programs for each requested bucket size (default: all
        configured buckets > 1), so the first live request after warm-up is
        a pure registry hit — zero lowers, zero compiles."""
        before = self.plan_cache.stats_snapshot()
        n_sigs = self.plan_cache.warm(
            self.pipeline, self.node, regions, virtual=self.virtual
        )
        buckets = tuple(
            b for b in (self.batch_sizes if buckets is None else buckets)
            if b > 1
        )
        seen = set()
        for region in regions:
            desc = self.describe(region)
            if desc.signature in seen:
                continue
            seen.add(desc.signature)
            for b in buckets:
                # prime with replicated copies of this region's real reads so
                # the jit traces (and XLA compiles) at the bucket shape now
                self.pull_described([desc] * b)
        after = self.plan_cache.stats_snapshot()
        return {
            "signatures": n_sigs,
            "buckets": len(buckets),
            **{f"{k}_delta": after[k] - before[k] for k in after},
        }


def execute(
    pipeline: Pipeline,
    mapper: Mapper,
    splitter: Optional[Splitter] = None,
    keep_outputs: bool = False,
    **executor_kw,
) -> StreamResult:
    """One-call convenience: stream the whole image through ``mapper``.

    ``keep_outputs`` is the run-time option; everything else in
    ``executor_kw`` goes to the :class:`StreamingExecutor` constructor."""
    executor = StreamingExecutor(pipeline, mapper, splitter, **executor_kw)
    return executor.run(keep_outputs=keep_outputs)
