"""Streaming executor (paper §II.B): pull the pipeline region by region.

The mapper picks a splitting strategy, then the executor processes regions on
a bounded memory footprint.  ``worker`` / ``n_workers`` select this worker's
slice of the static schedule, so the same driver runs standalone or as one
rank of a host-level parallel run (e.g. one process per pod host feeding its
devices).

Per-region pulls are extracted with ``compile_pull`` and jit-compiled; plans
are cached by (node, region size, origin parity) so uniform stripes compile
once.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import Pipeline
from repro.core.process_object import Mapper, PersistentFilter
from repro.core.region import ImageRegion
from repro.core.scheduling import lpt_schedule, static_schedule
from repro.core.splitting import Splitter, StripeSplitter


@dataclasses.dataclass
class StreamResult:
    regions_processed: int
    pixels_processed: int
    persistent_results: Dict[str, Dict[str, jnp.ndarray]]
    #: per-region pixel outputs, only kept when ``keep_outputs=True``
    outputs: Optional[List[np.ndarray]] = None


class StreamingExecutor:
    def __init__(
        self,
        pipeline: Pipeline,
        mapper: Mapper,
        splitter: Optional[Splitter] = None,
        worker: int = 0,
        n_workers: int = 1,
        scheduler: str = "static",
        cost_fn: Optional[Callable[[ImageRegion], float]] = None,
        use_jit: bool = True,
    ):
        if scheduler not in ("static", "lpt"):
            raise ValueError(scheduler)
        self.pipeline = pipeline
        self.mapper = mapper
        self.splitter = splitter or StripeSplitter(n_splits=max(1, n_workers) * 4)
        self.worker = worker
        self.n_workers = n_workers
        self.scheduler = scheduler
        self.cost_fn = cost_fn or (lambda r: float(r.num_pixels))
        self.use_jit = use_jit

    def my_regions(self) -> List[ImageRegion]:
        info = self.pipeline.info(self.mapper)
        regions = self.splitter.split(info.full_region, info)
        if self.scheduler == "static":
            sched = static_schedule(regions, self.n_workers)
        else:
            sched = lpt_schedule(regions, self.n_workers, self.cost_fn)
        return [regions[i] for i in sched[self.worker]]

    def run(self, keep_outputs: bool = False) -> StreamResult:
        pipeline, mapper = self.pipeline, self.mapper
        info = pipeline.info(mapper)
        mapper.begin(info)

        # persistent-filter state lives across regions (paper's Reset)
        pstates = {p.name: p.reset() for p in pipeline.persistent_nodes()}

        def hook(node: PersistentFilter, region: ImageRegion, inputs):
            pstates[node.name] = node.accumulate(pstates[node.name], region, *inputs)

        outputs: List[np.ndarray] = []
        pixels = 0
        regions = self.my_regions()
        for region in regions:
            if self.use_jit and not pipeline.persistent_nodes():
                plan = pipeline.compile_pull(mapper, region)
                arrays = plan.read_sources()
                data = jax.jit(plan.fn)(arrays)
            else:
                # persistent accumulation runs through the eager pull so the
                # hook observes every region exactly once
                data = pipeline.pull(mapper, region, persistent_hook=hook)
            data = np.asarray(data)
            mapper.consume(region, data)
            pixels += region.num_pixels
            if keep_outputs:
                outputs.append(data)

        # paper's Synthesis: finalize persistent state after the region loop
        presults = {
            p.name: p.synthesize(pstates[p.name]) for p in pipeline.persistent_nodes()
        }
        mapper.end()
        return StreamResult(
            regions_processed=len(regions),
            pixels_processed=pixels,
            persistent_results=presults,
            outputs=outputs if keep_outputs else None,
        )


def execute(
    pipeline: Pipeline,
    mapper: Mapper,
    splitter: Optional[Splitter] = None,
    **kw,
) -> StreamResult:
    """One-call convenience: stream the whole image through ``mapper``."""
    return StreamingExecutor(pipeline, mapper, splitter, **kw).run(**{})
