"""Process objects: Sources, Filters, Mappers (paper §II.B–C).

A pipeline is a directed graph of process objects.  The execution protocol is
the three-phase pull of ITK/OTB, realized functionally:

  1. ``output_info``      — metadata flows *downstream* (sources derive it
                            from their metadata; filters may transform it,
                            e.g. resampling changes the output size).
  2. ``requested_region`` — region requests flow *upstream*; filters may
                            enlarge the request (neighborhood halos).
  3. ``generate``         — pixel data flows *downstream*, one requested
                            region at a time.

``generate`` is a pure array→array function (jit-compatible); all region
bookkeeping happens on the host in the streaming / parallel drivers.

The paper's key dichotomy (§II.C.1):

  * region-independent process objects produce identical pixels whatever the
    requested region → transparently parallelizable by domain decomposition;
  * *Persistent* process objects accumulate state across regions
    (``reset`` / ``accumulate`` / ``synthesize``); their parallel flavor
    aggregates state with collectives (MPI in the paper, ``lax.psum`` & co
    here).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.region import ImageRegion, whole


@dataclasses.dataclass(frozen=True)
class GeoTransform:
    """Affine geo-referencing: pixel (row, col) -> world (x, y)."""

    origin_x: float = 0.0
    origin_y: float = 0.0
    spacing_x: float = 1.0
    spacing_y: float = -1.0  # north-up rasters have negative y spacing

    def pixel_to_world(self, row: float, col: float) -> Tuple[float, float]:
        return (self.origin_x + col * self.spacing_x, self.origin_y + row * self.spacing_y)

    def scaled(self, frow: float, fcol: float) -> "GeoTransform":
        """Geo transform after resampling by factors (frow, fcol) in pixel density."""
        return GeoTransform(self.origin_x, self.origin_y, self.spacing_x / fcol, self.spacing_y / frow)


@dataclasses.dataclass(frozen=True)
class ImageInfo:
    """Largest-possible-region metadata (paper: "information ... generated from
    metadatas" — image size, pixel spacing, etc.)."""

    rows: int
    cols: int
    bands: int
    dtype: Any = np.float32
    geo: GeoTransform = GeoTransform()
    nodata: Optional[float] = None

    @property
    def full_region(self) -> ImageRegion:
        return whole(self.rows, self.cols)

    @property
    def bytes_per_pixel(self) -> int:
        return int(np.dtype(self.dtype).itemsize) * self.bands

    @property
    def total_bytes(self) -> int:
        return self.rows * self.cols * self.bytes_per_pixel


#: monotonic construction counter — plan signatures embed ``_serial`` (never
#: recycled, unlike ``id()``) so a process-wide plan registry stays sound
_SERIALS = itertools.count()


class ProcessObject:
    """Base class. Subclasses override the three protocol methods."""

    #: number of image inputs (0 for sources)
    n_inputs: int = 1
    #: paper §II.C.1 — identical pixels whatever the requested region?
    region_independent: bool = True
    #: relative per-pixel cost estimate, drives cost-weighted load balancing
    cost_per_pixel: float = 1.0

    def __init__(self, name: Optional[str] = None):
        self.name = name or type(self).__name__
        self._serial = next(_SERIALS)

    # -- phase 1: metadata downstream ---------------------------------------
    def output_info(self, *input_infos: ImageInfo) -> ImageInfo:
        if self.n_inputs == 0:
            raise NotImplementedError(f"{self.name}: sources must implement output_info()")
        return input_infos[0]

    # -- phase 2: requested region upstream ----------------------------------
    def requested_region(
        self, out_region: ImageRegion, *input_infos: ImageInfo
    ) -> Tuple[ImageRegion, ...]:
        """Input region(s) needed to produce ``out_region``.

        May exceed the input's largest possible region; the driver clamps and
        boundary-pads.  Default: same region for every input.
        """
        return tuple(out_region for _ in range(self.n_inputs))

    # -- phase 3: data downstream ---------------------------------------------
    #: set True on filters whose pixels depend on *absolute* output
    #: coordinates (warps, coordinate-driven sources).  Their ``generate``
    #: receives two extra kwargs:
    #:   origin        — absolute (row0, col0) of the output region (row0 is a
    #:                   traced scalar under the SPMD strip plan);
    #:   input_origins — per input, absolute (row0, col0) of the array's first
    #:                   pixel (row0 possibly traced; col0 always static).
    #: Such filters must do ALL coordinate arithmetic from these, never from
    #: ``out_region.index`` / their recomputed requested region.
    needs_origin: bool = False

    def generate(self, out_region: ImageRegion, *inputs: jnp.ndarray) -> jnp.ndarray:
        """Produce pixels for ``out_region``.

        ``inputs[i]`` has shape (req_rows, req_cols, bands_i) covering exactly
        ``requested_region(out_region, ...)[i]`` (boundary-padded) — except
        for ``needs_origin`` filters under the strip plan, where the driver
        may widen input columns; use ``input_origins``.  Must be a pure jax
        function of the arrays — region arguments only select static
        shapes/offsets.
        """
        raise NotImplementedError

    def plan_key(self, out_region: ImageRegion):
        """Extra *static* data baked into this node's compiled trace, beyond
        array shapes and boundary pads.  Canonical plans only share one
        compiled function across regions whose plan keys match, so filters
        whose ``generate`` depends on absolute coordinates through host-side
        constants (e.g. a resampling phase) must return a hashable key here.
        Translation-invariant filters return None; filters that can consume
        *traced* absolute coordinates use ``needs_origin`` instead."""
        return None

    def window_bound(
        self, out_size: Tuple[int, int], *input_infos: ImageInfo
    ) -> Tuple[Optional[Tuple[int, int]], ...]:
        """Static per-input bound on ``requested_region`` size — the *window
        spec* hook of the plan layer's windowed reads.

        A ``needs_origin`` filter whose requested regions drift fractionally
        with the output origin (warps) makes every region's plan signature
        unique, forcing one trace per region.  Returning a conservative
        static ``(rows, cols)`` bound here — valid for *any* output region of
        ``out_size``, whatever its origin — lets the plan layer replace the
        exact drifting request with a fixed-shape bounding window anchored at
        the request origin (columns shifted in-image).  The window's absolute
        origin is threaded into the compiled function as traced scalars
        (``input_origins``), so every region of one size shares a single
        trace, and the SPMD driver lowers the window to a
        ``jax.lax.dynamic_slice`` of the halo-exchanged shard.

        Only consulted for ``needs_origin`` filters, which must sample purely
        by absolute coordinates (``origin`` / ``input_origins``) with
        edge-clamped out-of-window taps — the window is then exactly
        equivalent to the eager pull's edge-padded exact request.  Return
        ``None`` for an input to keep its exact request (no windowing).
        """
        return tuple(None for _ in range(self.n_inputs))

    # -- the plan layer's Pallas fast path -----------------------------------
    def pointwise_fn(self) -> Optional[Callable]:
        """Pure elementwise array→array equivalent of ``generate`` — the
        *fusion* hook of the plan layer's Pallas fast path.

        A zero-halo filter whose ``generate(region, x)`` is ``f(x)`` applied
        elementwise (dtype casts, linear rescales, band arithmetic) may
        return that ``f`` here.  The plan walk then folds a single-consumer
        chain of such nodes into the downstream Pallas kernel's body — ``f``
        runs on the VMEM tile ahead of the neighborhood math, and the
        chain's HBM intermediates are never materialized.  ``f`` must
        preserve the leading (row, col) shape and be region-independent: it
        is applied to *haloed, edge-padded* tiles, where elementwise
        semantics make pad-then-apply equal apply-then-pad, so fused and
        unfused plans agree bit-exactly.  Return None (default) to never
        fuse.
        """
        return None

    def pallas_plan(self) -> bool:
        """Decision hook of the Pallas fast path, consulted by BOTH the
        describe and the lower walk — the decision is recorded in the plan
        signature and the lower pass re-asserts signature equality, so it
        must be deterministic in (node, environment); kernel-backed filters
        return ``kernels.ops.resolve_use_pallas(self.use_pallas)``.  True
        means the plan layer replaces this node's ``generate`` with the
        fused body from :meth:`pallas_body` and fuses upstream pointwise
        chains into it."""
        return False

    def pallas_body(self, pre_fns: Tuple[Optional[Callable], ...]) -> Callable:
        """Body hook of the Pallas fast path, called at LOWER time only.

        ``pre_fns`` has one entry per input: the composed ``pointwise_fn``
        chain fused onto that input (to be applied to the raw upstream
        array inside the kernel), or None when nothing fused.  Returns
        ``body(*inputs) -> out`` replacing ``generate`` in the lowered
        closure; ``inputs[i]`` is the array delivered below the fused
        chain, covering this node's i-th requested region."""
        raise NotImplementedError(
            f"{self.name}: pallas_plan() is True but pallas_body() is missing"
        )

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


class Source(ProcessObject):
    """Initiates a pipeline (paper: e.g. image file reader).

    Region independence for a source means pixels are a pure function of
    absolute pixel coordinates (true for file readers and coordinate-driven
    synthetic sources).
    """

    n_inputs = 0

    def output_info(self) -> ImageInfo:  # type: ignore[override]
        raise NotImplementedError

    def generate(self, out_region: ImageRegion) -> jnp.ndarray:  # type: ignore[override]
        raise NotImplementedError

    def read_record(self):
        """Extra *static* data stamped into this source's plan-signature read
        records (the source-side analogue of :meth:`plan_key`).  Tiled
        containers return their tile geometry + overview level here so a
        re-tiled or re-leveled container never aliases a flat source's plan;
        plain sources return None.  Must be hashable and deterministic —
        describe and lower walks both record it and assert equality."""
        return None


class Filter(ProcessObject):
    """Transforms data objects."""


@dataclasses.dataclass
class Reduction:
    """How to combine per-region / per-device persistent state (paper: MPI
    many-to-one / many-to-many patterns in ``Synthesis``)."""

    kind: str  # 'sum' | 'min' | 'max' | 'concat'

    def combine(self, a, b):
        if self.kind == "sum":
            return jnp.asarray(a) + jnp.asarray(b)
        if self.kind == "min":
            return jnp.minimum(a, b)
        if self.kind == "max":
            return jnp.maximum(a, b)
        if self.kind == "concat":
            return jnp.concatenate([jnp.atleast_1d(a), jnp.atleast_1d(b)], axis=0)
        raise ValueError(self.kind)


class PersistentFilter(Filter):
    """Persists state across region updates (paper §II.C.1, e.g. pixel
    statistics).  ``state_reductions`` maps state-pytree leaves (by key) to the
    collective used to aggregate them across regions/devices."""

    region_independent = False  # the *state* depends on which regions were seen
    #: dict key -> Reduction for each entry of the state dict
    state_reductions: Dict[str, Reduction] = {}
    #: SPMD tiles may carry virtual padded rows/columns past the image
    #: border; mask-aware filters accept ``mask`` ((rows, cols, 1) bool,
    #: broadcastable — True = valid output pixel) in ``accumulate`` and
    #: ignore padded pixels.  The canonical plan always threads a mask-aware
    #: filter's absolute (row, col) origin through the compiled function as
    #: traced scalars and passes the derived in-trace 2-D validity mask
    #: (all-true on real geometry, pad rows/cols False on virtual padded
    #: tiles) — one registry body serves streaming, pool and SPMD alike.
    #: Filters without mask support can only run in parallel mode when the
    #: image divides evenly across the worker grid.
    supports_mask: bool = False

    def reset(self) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def accumulate(
        self,
        state: Dict[str, jnp.ndarray],
        out_region: ImageRegion,
        *inputs: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
    ) -> Dict[str, jnp.ndarray]:
        """Fold one region's inputs into ``state``.

        ``out_region`` is *canonical*: its shape is always correct, but under
        the compiled drivers (plan cache, SPMD strip plan) its origin may be
        that of another signature-equal region, baked in at trace time.
        Accumulate from the input arrays only; a filter whose state really
        depends on absolute coordinates must override ``plan_key`` to return
        ``out_region.index`` so no two regions share a trace."""
        raise NotImplementedError

    def synthesize(self, state: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Final many-to-one step, runs after all aggregation."""
        return state

    def combine_states(self, a: Dict[str, jnp.ndarray], b: Dict[str, jnp.ndarray]):
        return {k: self.state_reductions[k].combine(a[k], b[k]) for k in a}

    # Persistent filters are pass-through for pixel data by default.
    def generate(self, out_region: ImageRegion, *inputs: jnp.ndarray) -> jnp.ndarray:
        return inputs[0]


class Mapper(ProcessObject):
    """Terminates a pipeline: writes to disk or hands data to another system.

    Drivers call ``begin(info)`` once, then ``consume(region, data)`` for each
    produced region (possibly from several workers for parallel mappers), then
    ``end()``.
    """

    #: True when ``consume`` may be called concurrently for disjoint regions
    #: (MPI-IO-style writers, disjoint in-memory assembly).  The pool driver
    #: serializes consume calls with a lock when this is False.
    thread_safe: bool = False

    def begin(self, info: ImageInfo) -> None:
        pass

    def consume(self, out_region: ImageRegion, data: np.ndarray) -> None:
        raise NotImplementedError

    def end(self) -> None:
        pass

    def generate(self, out_region: ImageRegion, *inputs: jnp.ndarray) -> jnp.ndarray:
        # mappers pass pixels through unchanged (identity in the data graph)
        return inputs[0]


def window_request(
    req: ImageRegion, bound: Tuple[int, int], in_info: ImageInfo
) -> ImageRegion:
    """Replace an exact (drifting) request with its static-shape bounding
    window — the canonical *window spec* of the plan layer.

    Rows are anchored at the request origin: spill past the image border is
    clamped + edge-padded like any other request (interior windows stay
    pad-free, so interior regions share one signature; the SPMD driver
    realizes the spill by halo edge-replication instead).  Columns are
    shifted in-image where possible (full-width strips would otherwise bake
    per-region column pads into the signature); shifting is sound because
    ``needs_origin`` consumers sample by absolute coordinates and their
    out-of-window taps edge-clamp exactly where the image edge lies.
    """
    wrows, wcols = bound
    if req.rows > wrows or req.cols > wcols:
        raise ValueError(
            f"window_bound {bound} smaller than requested region {req.size} — "
            "the bound must be conservative for every output region of its size"
        )
    c0 = max(0, min(req.col0, in_info.cols - wcols))
    return ImageRegion((req.row0, c0), (wrows, wcols))


def windowed_requests(
    node: ProcessObject,
    out_size: Tuple[int, int],
    reqs: Sequence[ImageRegion],
    in_infos: Sequence[ImageInfo],
) -> Tuple[Tuple[ImageRegion, ...], Tuple[Optional[Tuple[int, int]], ...]]:
    """Apply window classification to one node's requests.

    Returns ``(requests, bounds)``: per input, the window region (when the
    node is ``needs_origin`` and declares a bound) or the exact request, plus
    the static bound (``None`` for unwindowed inputs).  Shared by the
    describe/lower walk and the SPMD strip prober so both see identical
    window geometry.
    """
    if not getattr(node, "needs_origin", False):
        return tuple(reqs), tuple(None for _ in reqs)
    bounds = tuple(node.window_bound(out_size, *in_infos))
    if len(bounds) != len(reqs):
        raise ValueError(
            f"{node.name}: window_bound returned {len(bounds)} entries for "
            f"{len(reqs)} inputs"
        )
    out = tuple(
        window_request(r, b, info) if b is not None else r
        for r, b, info in zip(reqs, bounds, in_infos)
    )
    return out, bounds


def boundary_pad(
    array: jnp.ndarray, have: ImageRegion, want: ImageRegion
) -> jnp.ndarray:
    """Edge-replicate ``array`` (covering ``have``) out to ``want`` ⊇ have.

    This is the boundary condition applied when a requested region spills over
    the image border (ITK's ZeroFlux/replicate boundary).
    """
    if have == want:
        return array
    pad_top = have.row0 - want.row0
    pad_bot = want.row1 - have.row1
    pad_left = have.col0 - want.col0
    pad_right = want.col1 - have.col1
    assert min(pad_top, pad_bot, pad_left, pad_right) >= 0, (have, want)
    pad_width = [(pad_top, pad_bot), (pad_left, pad_right)] + [(0, 0)] * (array.ndim - 2)
    return jnp.pad(array, pad_width, mode="edge")
