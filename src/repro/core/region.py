"""Region algebra for the pipeline framework.

The paper's execution model (§II.B) is region-driven: mappers pull *requested
regions* upstream and data flows back downstream.  ``ImageRegion`` is the
2-D index/size pair used everywhere (rows × cols, band axis is implicit and
never split — the paper writes row-wise interleaved pixels, §II.D).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple


@dataclasses.dataclass(frozen=True)
class ImageRegion:
    """A rectangular region: ``index`` = (row0, col0), ``size`` = (rows, cols)."""

    index: Tuple[int, int]
    size: Tuple[int, int]

    def __post_init__(self):
        if self.size[0] < 0 or self.size[1] < 0:
            raise ValueError(f"negative region size: {self.size}")

    # -- accessors ---------------------------------------------------------
    @property
    def row0(self) -> int:
        return self.index[0]

    @property
    def col0(self) -> int:
        return self.index[1]

    @property
    def rows(self) -> int:
        return self.size[0]

    @property
    def cols(self) -> int:
        return self.size[1]

    @property
    def row1(self) -> int:  # one past the end
        return self.index[0] + self.size[0]

    @property
    def col1(self) -> int:
        return self.index[1] + self.size[1]

    @property
    def num_pixels(self) -> int:
        return self.rows * self.cols

    def is_empty(self) -> bool:
        return self.rows == 0 or self.cols == 0

    # -- algebra -----------------------------------------------------------
    def intersect(self, other: "ImageRegion") -> "ImageRegion":
        r0 = max(self.row0, other.row0)
        c0 = max(self.col0, other.col0)
        r1 = min(self.row1, other.row1)
        c1 = min(self.col1, other.col1)
        if r1 <= r0 or c1 <= c0:
            return ImageRegion((r0, c0), (0, 0))
        return ImageRegion((r0, c0), (r1 - r0, c1 - c0))

    def union_bbox(self, other: "ImageRegion") -> "ImageRegion":
        if self.is_empty():
            return other
        if other.is_empty():
            return self
        r0 = min(self.row0, other.row0)
        c0 = min(self.col0, other.col0)
        r1 = max(self.row1, other.row1)
        c1 = max(self.col1, other.col1)
        return ImageRegion((r0, c0), (r1 - r0, c1 - c0))

    def pad(self, radius_rows: int, radius_cols: int | None = None) -> "ImageRegion":
        """Enlarge by a halo radius (the requested-region enlargement of §II.C.1)."""
        if radius_cols is None:
            radius_cols = radius_rows
        return ImageRegion(
            (self.row0 - radius_rows, self.col0 - radius_cols),
            (self.rows + 2 * radius_rows, self.cols + 2 * radius_cols),
        )

    def clamp(self, bounds: "ImageRegion") -> "ImageRegion":
        """Crop to ``bounds`` (used after pad() at image borders)."""
        return self.intersect(bounds)

    def contains(self, other: "ImageRegion") -> bool:
        if other.is_empty():
            return True
        return (
            self.row0 <= other.row0
            and self.col0 <= other.col0
            and self.row1 >= other.row1
            and self.col1 >= other.col1
        )

    def shift(self, drow: int, dcol: int) -> "ImageRegion":
        return ImageRegion((self.row0 + drow, self.col0 + dcol), self.size)

    def relative_to(self, outer: "ImageRegion") -> "ImageRegion":
        """This region expressed in coordinates local to ``outer``."""
        return ImageRegion((self.row0 - outer.row0, self.col0 - outer.col0), self.size)

    def slices(self) -> Tuple[slice, slice]:
        """numpy/jnp slices for indexing an array whose origin is (0, 0)."""
        return slice(self.row0, self.row1), slice(self.col0, self.col1)

    def iter_rows(self) -> Iterator[int]:
        return iter(range(self.row0, self.row1))

    def __str__(self) -> str:  # compact, used in logs
        return f"[{self.row0}:{self.row1}, {self.col0}:{self.col1}]"


def whole(rows: int, cols: int) -> ImageRegion:
    return ImageRegion((0, 0), (rows, cols))


def tile_cover(
    region: ImageRegion,
    tile_rows: int,
    tile_cols: int,
    bounds: ImageRegion | None = None,
) -> Iterator[Tuple[int, int, ImageRegion]]:
    """Iterate the fixed tile grid cells covering ``region``.

    Yields ``(ty, tx, tile_region)`` where ``tile_region`` is the full extent
    of grid cell ``(ty, tx)`` — clipped to ``bounds`` when given (ragged
    right/bottom tiles of an image that is not a tile-size multiple), *not*
    intersected with ``region``.  This is the region↔tile algebra shared by
    the tiled container (:mod:`repro.raster.tiled`): a windowed read visits
    exactly these cells, a writer flushes a cell once its pixels are covered.
    """
    if region.is_empty():
        return
    ty0, tx0 = region.row0 // tile_rows, region.col0 // tile_cols
    ty1, tx1 = (region.row1 - 1) // tile_rows, (region.col1 - 1) // tile_cols
    for ty in range(ty0, ty1 + 1):
        for tx in range(tx0, tx1 + 1):
            tile = ImageRegion(
                (ty * tile_rows, tx * tile_cols), (tile_rows, tile_cols)
            )
            if bounds is not None:
                tile = tile.clamp(bounds)
            yield ty, tx, tile
