"""Pipeline DAG + the three-phase pull protocol (paper §II.B).

``Pipeline`` wires process objects into a directed graph and implements:

  * ``update_information()``   — phase 1, metadata downstream;
  * ``pull(node, region)``     — phases 2+3 for one requested region (eager);
  * ``compile_pull(node, region)`` — symbolic version: extracts the set of
    source reads plus a pure jax function mapping source arrays → output
    pixels.  This is what the shard_map parallel driver partitions, and what
    ``jax.jit`` compiles for the streaming driver's hot loop.

Border semantics: at *every* producer→consumer edge, the consumer's request is
clamped against the producer's largest possible region and edge-replicated
back out (ITK boundary condition), so requests may safely spill over borders.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import (
    ImageInfo,
    Mapper,
    PersistentFilter,
    ProcessObject,
    Source,
    boundary_pad,
)
from repro.core.region import ImageRegion


class Pipeline:
    def __init__(self):
        self._inputs: Dict[int, List[ProcessObject]] = {}
        self._nodes: List[ProcessObject] = []
        self._infos: Optional[Dict[int, ImageInfo]] = None

    # -- graph construction --------------------------------------------------
    def add(self, obj: ProcessObject, inputs: Sequence[ProcessObject] = ()) -> ProcessObject:
        if len(inputs) != obj.n_inputs:
            raise ValueError(
                f"{obj.name}: expected {obj.n_inputs} inputs, got {len(inputs)}"
            )
        for up in inputs:
            if id(up) not in self._inputs:
                raise ValueError(f"{obj.name}: input {up.name} not in pipeline")
        self._nodes.append(obj)
        self._inputs[id(obj)] = list(inputs)
        self._infos = None  # invalidate
        return obj

    def inputs_of(self, obj: ProcessObject) -> List[ProcessObject]:
        return self._inputs[id(obj)]

    @property
    def nodes(self) -> List[ProcessObject]:
        return list(self._nodes)

    def sources(self) -> List[Source]:
        return [n for n in self._nodes if isinstance(n, Source)]

    def mappers(self) -> List[Mapper]:
        return [n for n in self._nodes if isinstance(n, Mapper)]

    def persistent_nodes(self) -> List[PersistentFilter]:
        return [n for n in self._nodes if isinstance(n, PersistentFilter)]

    # -- phase 1: UpdateOutputInformation -------------------------------------
    def update_information(self) -> Dict[int, ImageInfo]:
        """Propagate metadata downstream (nodes are stored in insertion order,
        which ``add`` guarantees is topological)."""
        if self._infos is None:
            infos: Dict[int, ImageInfo] = {}
            for node in self._nodes:
                in_infos = [infos[id(up)] for up in self._inputs[id(node)]]
                infos[id(node)] = node.output_info(*in_infos)
            self._infos = infos
        return self._infos

    def info(self, node: ProcessObject) -> ImageInfo:
        return self.update_information()[id(node)]

    # -- phases 2+3: eager pull ------------------------------------------------
    def pull(
        self,
        node: ProcessObject,
        out_region: ImageRegion,
        persistent_hook: Optional[Callable] = None,
        _cache: Optional[Dict] = None,
    ) -> jnp.ndarray:
        """Produce pixels of ``node`` for ``out_region`` (clamped + padded to
        the exact requested size).  ``persistent_hook(node, region, inputs)``
        is invoked for every PersistentFilter encountered (the streaming /
        parallel drivers use it to accumulate state)."""
        infos = self.update_information()
        cache = _cache if _cache is not None else {}
        key = (id(node), out_region)
        if key in cache:
            return cache[key]

        own_info = infos[id(node)]
        clamped = out_region.clamp(own_info.full_region)
        if clamped.is_empty():
            raise ValueError(f"{node.name}: request {out_region} outside image")

        ups = self._inputs[id(node)]
        if not ups:  # source
            data = node.generate(clamped)  # type: ignore[call-arg]
        else:
            in_infos = [infos[id(u)] for u in ups]
            reqs = node.requested_region(clamped, *in_infos)
            inputs = [
                self.pull(u, r, persistent_hook, cache) for u, r in zip(ups, reqs)
            ]
            if isinstance(node, PersistentFilter) and persistent_hook is not None:
                persistent_hook(node, clamped, inputs)
            if getattr(node, "needs_origin", False):
                data = node.generate(
                    clamped,
                    *inputs,
                    origin=clamped.index,
                    input_origins=tuple(r.index for r in reqs),
                )
            else:
                data = node.generate(clamped, *inputs)
        expect = (clamped.rows, clamped.cols)
        if tuple(data.shape[:2]) != expect:
            raise ValueError(
                f"{node.name}: generate() returned {data.shape[:2]}, expected {expect}"
            )
        data = boundary_pad(data, clamped, out_region)
        cache[key] = data
        return data

    # -- symbolic pull: extract (source reads, pure function) ------------------
    def compile_pull(self, node: ProcessObject, out_region: ImageRegion) -> "PullPlan":
        """Build a :class:`PullPlan` whose ``fn`` maps source arrays (covering
        the plan's clamped source regions, in plan order) to the pixels of
        ``node`` over ``out_region``.  ``fn`` is pure jax and jit-able."""
        infos = self.update_information()
        reads: List[Tuple[Source, ImageRegion, ImageRegion]] = []
        read_index: Dict[Tuple[int, ImageRegion], int] = {}
        steps: List[Tuple] = []  # closure program, built by recursion

        def build(n: ProcessObject, region: ImageRegion) -> Callable:
            own_info = infos[id(n)]
            clamped = region.clamp(own_info.full_region)
            ups = self._inputs[id(n)]
            if not ups:
                k = (id(n), clamped)
                if k not in read_index:
                    read_index[k] = len(reads)
                    reads.append((n, clamped, region))  # type: ignore[arg-type]
                idx = read_index[k]

                def run_source(arrays, _idx=idx, _clamped=clamped, _region=region):
                    return boundary_pad(arrays[_idx], _clamped, _region)

                return run_source

            in_infos = [infos[id(u)] for u in ups]
            reqs = n.requested_region(clamped, *in_infos)
            child_fns = [build(u, r) for u, r in zip(ups, reqs)]

            def run_node(arrays, _n=n, _clamped=clamped, _region=region,
                         _fns=child_fns, _reqs=reqs):
                ins = [f(arrays) for f in _fns]
                if getattr(_n, "needs_origin", False):
                    out = _n.generate(
                        _clamped,
                        *ins,
                        origin=_clamped.index,
                        input_origins=tuple(r.index for r in _reqs),
                    )
                else:
                    out = _n.generate(_clamped, *ins)
                return boundary_pad(out, _clamped, _region)

            return run_node

        fn = build(node, out_region)
        return PullPlan(reads=reads, fn=fn, out_region=out_region)


@dataclasses.dataclass
class PullPlan:
    """``reads``: list of (source, clamped_region, requested_region);
    ``fn(arrays)`` with arrays[i] covering reads[i]'s clamped region returns
    the output pixels."""

    reads: List[Tuple[Source, ImageRegion, ImageRegion]]
    fn: Callable[[Sequence[jnp.ndarray]], jnp.ndarray]
    out_region: ImageRegion

    def read_sources(self) -> List[jnp.ndarray]:
        return [s.generate(clamped) for s, clamped, _ in self.reads]

    def run(self) -> jnp.ndarray:
        return self.fn(self.read_sources())
