"""Pipeline DAG + the three-phase pull protocol (paper §II.B).

``Pipeline`` wires process objects into a directed graph and implements:

  * ``update_information()``   — phase 1, metadata downstream;
  * ``pull(node, region)``     — phases 2+3 for one requested region (eager);
  * ``describe_pull(node, region)`` — the cheap *describe* pass: source
    reads, canonical plan signature and origin scalars, with no closure
    construction.  Run once per region; on a plan-registry hit it is the
    only per-region graph work.
  * ``compile_pull(node, region)`` — describe **plus** the *lower* pass: the
    pure jax closure mapping source arrays → output pixels.  This is what
    the shard_map parallel driver partitions, and what ``jax.jit`` compiles
    for the streaming driver's hot loop.  ``lower_pull(desc)`` lowers an
    existing description (the :class:`~repro.core.execplan.PlanCache` calls
    it on registry misses only).

Plans are *canonical*: every region-dependent quantity that XLA must treat as
static (array shapes, boundary-pad widths, graph structure) is folded into
``PullPlan.signature``, while absolute coordinates consumed by
``needs_origin`` filters are threaded through the compiled function as traced
scalar arguments.  Two regions with equal signatures (e.g. all interior
stripes of a uniform split) can therefore share one compiled function — the
streaming engine's :class:`~repro.core.streaming.PlanCache` keys on exactly
this.  Persistent-filter state is threaded through the pure function
(``fn(arrays, pstates, origins) -> (pixels, new_pstates)``), so pipelines
containing :class:`PersistentFilter` nodes compile instead of falling back to
the eager pull.

Border semantics: at *every* producer→consumer edge, the consumer's request is
clamped against the producer's largest possible region and edge-replicated
back out (ITK boundary condition), so requests may safely spill over borders.

Windowed reads: requests made by ``needs_origin`` nodes drift fractionally
with the output origin, which would give every region its own signature.
When the node declares :meth:`ProcessObject.window_bound`, every pass (eager
pull, describe, lower) replaces the exact request with a conservative
static-shape bounding window (``process_object.window_request``) whose
absolute origin is a traced scalar, so all regions of one size share a
single trace.  Windowed reads carry no boundary pads in the trace — border
spill is edge-replicated at the read stage — so border regions share the
interior signature too.

Virtual padded tiles: ``describe_pull(..., virtual=...)`` runs the same walk
against a *virtually padded* geometry.  Two modes exist: ``"grid"`` (the
default for ``virtual=True``) never clamps in **either** axis, so a tile of
a 2-D SPMD grid that spills past the real image rows *or* columns describes
exactly like an interior tile and shares the interior plan signature;
``"rows"`` is the restricted legacy mode (rows unclamped, columns clamp
in-image) for pipelines whose column borders are not virtualization-safe
(:meth:`Pipeline.virtual_cols_safe`).  Spilled rows/cols are materialized at
the read stage instead (edge-replicated halos under SPMD,
:func:`~repro.core.execplan.read_plan_sources`'s clamp+pad host-side).
Mask-aware persistent filters (``supports_mask``) always thread their output
region's absolute (row, col) origin through the plan as traced scalars and
accumulate under an in-trace 2-D validity mask (pixels inside the real
image), so the masked-persistent case runs through the very same registry
body — with an all-true mask on real geometry and pad rows/cols masked out
on virtual geometry.  :meth:`Pipeline.virtual_describe_mode` picks the
strongest safe mode per pipeline; the describe caller (streaming warm-up,
SPMD tile prober) must use the same mode so both land on one registry entry.

Pallas fast path: a node whose ``pallas_plan()`` hook is true lowers to the
fused kernel body from ``pallas_body()`` instead of its ``generate`` — and
single-consumer runs of pointwise nodes feeding it (``pointwise_fn`` hook:
dtype converts, band math, quantize-style rescales) fold INTO that body, so
a registry hit executes one fused Pallas call per strip instead of N jnp
passes with materialized HBM intermediates.  The fusion decision uses graph
structure and static node state only, is made identically by the describe
and the lower walk, and is recorded in the plan signature as a ``"pallas"``
step (kernel serial + the fused chain's serials), so Pallas and jnp plans of
one graph never collide in the registry and a warm registry sees zero new
lowers/compiles.  Fused chain nodes contribute no signature records of their
own — their pixels exist only inside the kernel's VMEM tiles.  Fusion
refuses (and the plan falls back to plain node records) for multi-consumer,
multi-input, origin-aware, persistent, plan-keyed or grid-changing nodes —
exactly the nodes whose pixels or state must stay observable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.execplan import PlanDescription, read_plan_sources
from repro.core.process_object import (
    ImageInfo,
    Mapper,
    PersistentFilter,
    ProcessObject,
    Source,
    boundary_pad,
    windowed_requests,
)
from repro.core.region import ImageRegion


def _normalize_virtual(virtual) -> "bool | str":
    """Canonical virtual-describe mode: ``False`` (exact walk), ``"rows"``
    (rows unclamped, columns clamp in-image) or ``"grid"`` (neither axis
    clamps).  ``True`` means the full 2-D mode — the 1-D strip path is the
    ``nc = 1`` column of the grid, not a separate dialect."""
    if virtual is False or virtual is None:
        return False
    if virtual is True or virtual == "grid":
        return "grid"
    if virtual == "rows":
        return "rows"
    raise ValueError(f"unknown virtual describe mode: {virtual!r}")


class Pipeline:
    def __init__(self):
        self._inputs: Dict[int, List[ProcessObject]] = {}
        self._nodes: List[ProcessObject] = []
        self._infos: Optional[Dict[int, ImageInfo]] = None

    # -- graph construction --------------------------------------------------
    def add(self, obj: ProcessObject, inputs: Sequence[ProcessObject] = ()) -> ProcessObject:
        if len(inputs) != obj.n_inputs:
            raise ValueError(
                f"{obj.name}: expected {obj.n_inputs} inputs, got {len(inputs)}"
            )
        for up in inputs:
            if id(up) not in self._inputs:
                raise ValueError(f"{obj.name}: input {up.name} not in pipeline")
        self._nodes.append(obj)
        self._inputs[id(obj)] = list(inputs)
        self._infos = None  # invalidate
        return obj

    def inputs_of(self, obj: ProcessObject) -> List[ProcessObject]:
        return self._inputs[id(obj)]

    @property
    def nodes(self) -> List[ProcessObject]:
        return list(self._nodes)

    def sources(self) -> List[Source]:
        return [n for n in self._nodes if isinstance(n, Source)]

    def mappers(self) -> List[Mapper]:
        return [n for n in self._nodes if isinstance(n, Mapper)]

    def persistent_nodes(self) -> List[PersistentFilter]:
        return [n for n in self._nodes if isinstance(n, PersistentFilter)]

    def virtual_rows_safe(self) -> bool:
        """True when virtual (unclamped-row) describes cannot change pixels.

        The two walk modes agree exactly when every request that can spill
        past an image's row extent lands on a **source** (the read stage
        materializes the spill by edge replication either way) — possibly
        through *row-transparent* filters, whose requests are row-identity:
        a streamable row-identity filter is row-local, so edge replication
        commutes through it (``replicate(f(x)) == f(replicate(x))``), and on
        the Pallas path fused pointwise chains compute over the padded source
        read outright.  The unsafe shape is spilled rows reaching a
        row-*stencil* intermediate — the exact walk clamps there and
        edge-replicates that filter's OUTPUT rows, while the virtual walk
        computes the spilled rows from edge-replicated SOURCE pixels.  For
        stacked neighborhood filters (e.g. smoothing → gradient) those
        conventions produce genuinely different border rows, so such
        pipelines must keep exact describes.

        The probe is structural (each consumer's requests over top and
        bottom border strips of its own grid, graph + static node state
        only), so every describe/lower pair classifies identically.
        """
        return self._virtual_axis_safe("rows")

    def virtual_cols_safe(self) -> bool:
        """True when virtual (unclamped-column) describes cannot change
        pixels — the column mirror of :meth:`virtual_rows_safe`: spill past
        an image's column extent must reach sources only, possibly through
        column-transparent (column-identity-request) filters.  Both axes
        safe ⇒ ``"grid"`` describes are exact; see
        :meth:`virtual_describe_mode`."""
        return self._virtual_axis_safe("cols")

    def virtual_describe_mode(self) -> "bool | str":
        """The strongest virtual describe mode this pipeline supports:
        ``"grid"`` (neither axis clamps — required by 2-D tile-grid SPMD),
        ``"rows"`` (rows-only virtualization), or ``False`` (exact describes
        only).  Requires every persistent filter to be mask-aware — an
        unmaskable accumulator would double-count edge-replicated pad pixels.
        Every describe producer for one pipeline (streaming warm-up, the
        SPMD tile prober, the serving engine) must take its mode from here,
        or warm-up and execution would land on different registry entries."""
        if not all(p.supports_mask for p in self.persistent_nodes()):
            return False
        if not self._virtual_axis_safe("rows"):
            return False
        return "grid" if self._virtual_axis_safe("cols") else "rows"

    def _virtual_axis_safe(self, axis: str) -> bool:
        """Shared structural probe behind :meth:`virtual_rows_safe` /
        :meth:`virtual_cols_safe` — identical propagation logic, border
        probes and identity checks taken along ``axis``."""
        infos = self.update_information()
        on_rows = axis == "rows"

        def lo(r: ImageRegion) -> int:
            return r.row0 if on_rows else r.col0

        def hi(r: ImageRegion) -> int:
            return r.row1 if on_rows else r.col1

        def extent(info: ImageInfo) -> int:
            return info.rows if on_rows else info.cols

        probes_of = {}  # id(n) -> pair of border probe regions on `axis`
        reqs_of = {}  # id(n) -> per-probe request tuples
        for n in self._nodes:
            ups = self._inputs[id(n)]
            if not ups:
                continue
            own = infos[id(n)]
            in_infos = [infos[id(u)] for u in ups]
            if on_rows:
                pr = max(1, min(own.rows, 8))
                probes = (
                    ImageRegion((0, 0), (pr, own.cols)),
                    ImageRegion((own.rows - pr, 0), (pr, own.cols)),
                )
            else:
                pc = max(1, min(own.cols, 8))
                probes = (
                    ImageRegion((0, 0), (own.rows, pc)),
                    ImageRegion((0, own.cols - pc), (own.rows, pc)),
                )
            probes_of[id(n)] = probes
            reqs_of[id(n)] = tuple(
                n.requested_region(probe, *in_infos) for probe in probes
            )

        def transparent(u) -> bool:
            # every request of u is axis-identity with its probe region
            if id(u) not in reqs_of:
                return False  # sources handled by the caller
            return all(
                lo(req) == lo(probe) and hi(req) == hi(probe)
                for probe, reqs in zip(probes_of[id(u)], reqs_of[id(u)])
                for req in reqs
            )

        # propagate "may receive out-of-image rows/cols" consumer→producer
        # (insertion order is topological, so reverse order visits every
        # consumer before its producers)
        spilled = set()
        for n in reversed(self._nodes):
            ups = self._inputs[id(n)]
            if not ups:
                continue
            in_infos = [infos[id(u)] for u in ups]
            for probe, reqs in zip(probes_of[id(n)], reqs_of[id(n)]):
                for u, upi, req in zip(ups, in_infos, reqs):
                    expands = lo(req) < 0 or hi(req) > extent(upi)
                    if not (expands or id(n) in spilled):
                        continue
                    if not self._inputs[id(u)]:
                        continue  # source: read-stage edge replication
                    if not transparent(u):
                        return False
                    spilled.add(id(u))
        return True

    # -- phase 1: UpdateOutputInformation -------------------------------------
    def update_information(self) -> Dict[int, ImageInfo]:
        """Propagate metadata downstream (nodes are stored in insertion order,
        which ``add`` guarantees is topological)."""
        if self._infos is None:
            infos: Dict[int, ImageInfo] = {}
            for node in self._nodes:
                in_infos = [infos[id(up)] for up in self._inputs[id(node)]]
                infos[id(node)] = node.output_info(*in_infos)
            self._infos = infos
        return self._infos

    def info(self, node: ProcessObject) -> ImageInfo:
        return self.update_information()[id(node)]

    # -- phases 2+3: eager pull ------------------------------------------------
    def pull(
        self,
        node: ProcessObject,
        out_region: ImageRegion,
        persistent_hook: Optional[Callable] = None,
        _cache: Optional[Dict] = None,
    ) -> jnp.ndarray:
        """Produce pixels of ``node`` for ``out_region`` (clamped + padded to
        the exact requested size).  ``persistent_hook(node, region, inputs)``
        is invoked for every PersistentFilter encountered (the streaming /
        parallel drivers use it to accumulate state)."""
        infos = self.update_information()
        cache = _cache if _cache is not None else {}
        key = (id(node), out_region)
        if key in cache:
            return cache[key]

        own_info = infos[id(node)]
        clamped = out_region.clamp(own_info.full_region)
        if clamped.is_empty():
            raise ValueError(f"{node.name}: request {out_region} outside image")

        ups = self._inputs[id(node)]
        if not ups:  # source
            data = node.generate(clamped)  # type: ignore[call-arg]
        else:
            in_infos = [infos[id(u)] for u in ups]
            reqs = node.requested_region(clamped, *in_infos)
            # the same window classification as the compiled plans, so the
            # eager pull is a bit-exact oracle for every executor (windows
            # shift float origins; needs_origin filters must treat any
            # request ⊇ the exact one identically up to rounding)
            reqs, _ = windowed_requests(node, clamped.size, reqs, in_infos)
            inputs = [
                self.pull(u, r, persistent_hook, cache) for u, r in zip(ups, reqs)
            ]
            if isinstance(node, PersistentFilter) and persistent_hook is not None:
                persistent_hook(node, clamped, inputs)
            if getattr(node, "needs_origin", False):
                data = node.generate(
                    clamped,
                    *inputs,
                    origin=clamped.index,
                    input_origins=tuple(r.index for r in reqs),
                )
            else:
                data = node.generate(clamped, *inputs)
        expect = (clamped.rows, clamped.cols)
        if tuple(data.shape[:2]) != expect:
            raise ValueError(
                f"{node.name}: generate() returned {data.shape[:2]}, expected {expect}"
            )
        data = boundary_pad(data, clamped, out_region)
        cache[key] = data
        return data

    # -- symbolic pull: describe (cheap) + lower (closure construction) --------
    def describe_pull(
        self, node: ProcessObject, out_region: ImageRegion,
        virtual: "bool | str" = False,
    ) -> PlanDescription:
        """The describe pass: reads + canonical signature + origin scalars
        for ``node`` over ``out_region``, with **no** closure construction.

        Runs the same recursion as :meth:`compile_pull` (so the signature is
        bit-identical) but skips building the O(graph) closure tree — on a
        plan-registry hit this is the only per-region graph work.

        ``virtual`` selects the padded-geometry walk: ``True`` / ``"grid"``
        never clamps in either axis, so a tile spilling past the image rows
        *or* columns yields the *interior* signature — the 2-D SPMD tile
        prober uses this to keep ragged grid splits on the registry path;
        ``"rows"`` is the restricted rows-only mode for pipelines where
        :meth:`virtual_cols_safe` is false."""
        return self._plan_walk(node, out_region, lower=False, virtual=virtual)

    def lower_pull(self, desc: PlanDescription) -> "PullPlan":
        """The lower pass: build the jittable closure for a described plan
        (re-walked in the description's real/virtual geometry mode).
        The plan registry calls this on misses only."""
        plan = self._plan_walk(
            desc.node, desc.out_region, lower=True, virtual=desc.virtual
        )
        assert plan.signature == desc.signature, (
            "describe/lower signature drift",
            desc.node.name,
        )
        return plan

    def compile_pull(self, node: ProcessObject, out_region: ImageRegion) -> "PullPlan":
        """Build a canonical :class:`PullPlan` for ``node`` over ``out_region``
        (describe + lower in one walk).

        ``canonical_fn(arrays, pstates, origins)`` maps source arrays (covering
        the plan's clamped source regions, in plan order), a persistent-state
        dict and the plan's dynamic origin scalars to
        ``(pixels, new_pstates)``.  Absolute coordinates of ``needs_origin``
        nodes are *not* baked in — they are read from ``origins`` so one
        compiled function serves every region with the same ``signature``."""
        return self._plan_walk(node, out_region, lower=True)

    def _plan_walk(
        self,
        node: ProcessObject,
        out_region: ImageRegion,
        lower: bool,
        virtual: "bool | str" = False,
    ):
        infos = self.update_information()
        virtual = _normalize_virtual(virtual)

        def clamp(region: ImageRegion, own_info: ImageInfo) -> ImageRegion:
            if not virtual:
                return region.clamp(own_info.full_region)
            if virtual == "grid":
                # fully virtual padded geometry: neither axis clamps — spill
                # in any direction is materialized at the read stage
                return region
            # "rows" mode: rows pass through unclamped (the read stage
            # materializes spilled rows by edge replication), columns still
            # clamp in-image so the column-pad statics match the real
            # interior signature
            c0 = max(region.col0, 0)
            c1 = min(region.col1, own_info.cols)
            if c1 < c0:
                c1 = c0
            return ImageRegion((region.row0, c0), (region.rows, c1 - c0))
        reads: List[Tuple[Source, ImageRegion, ImageRegion]] = []
        read_windows: List[Optional[Tuple[int, int]]] = []
        read_index: Dict[Tuple, int] = {}
        origin_values: List[int] = []
        sig: List[Tuple] = []  # canonical step records, built by recursion
        persistent: List[PersistentFilter] = []
        built: Dict[Tuple, Tuple[int, Callable]] = {}
        pallas_serials: List[int] = []  # nodes lowered to fused Pallas bodies
        fused_serials: List[int] = []  # pointwise nodes folded into a body

        # Pallas fusion census: a pointwise node may fold into its consumer's
        # kernel only when it has exactly ONE consumer in the graph —
        # otherwise its pixels are needed materialized elsewhere
        consumers: Dict[int, int] = {}
        for _n in self._nodes:
            for _u in self._inputs[id(_n)]:
                consumers[id(_u)] = consumers.get(id(_u), 0) + 1

        def fuse_chain(u, req):
            """Walk the run of fusable pointwise nodes up one input edge.

            Returns ``(chain, deep, deep_req)``: ``chain`` is the
            consumer→producer list of ``(node, pointwise_fn)`` folded into
            the kernel, ``deep`` the first node that stays materialized, and
            ``deep_req`` the region requested of it.  A node fuses only when
            it is a single-input, single-consumer pointwise filter
            (``pointwise_fn() is not None``) on its input's grid, with an
            identity requested region and no origin / persistent / plan-key
            semantics — anything else refuses and the chain stops there.
            The decision uses graph structure and static node state only, so
            the describe and the lower walk always agree.  Because every
            link shares one grid, the deep node clamps and edge-pads ``req``
            exactly where each chain node would have, and pointwise fns
            commute with edge padding — fused output is bit-equal to the
            unfused chain feeding the same kernel.
            """
            chain: List[Tuple[ProcessObject, Callable]] = []
            cur = u
            while True:
                fn = cur.pointwise_fn()
                if (
                    fn is None
                    or cur.n_inputs != 1
                    or consumers.get(id(cur), 0) != 1
                    or isinstance(cur, (PersistentFilter, Mapper))
                    or getattr(cur, "needs_origin", False)
                    or cur.plan_key(req) is not None
                ):
                    return chain, cur, req
                up = self._inputs[id(cur)][0]
                own, upi = infos[id(cur)], infos[id(up)]
                if (own.rows, own.cols) != (upi.rows, upi.cols):
                    return chain, cur, req
                if tuple(cur.requested_region(req, upi)) != (req,):
                    return chain, cur, req
                chain.append((cur, fn))
                cur = up

        def dyn(value: int) -> int:
            """Register a dynamic (traced) origin scalar; returns its slot."""
            origin_values.append(int(value))
            return len(origin_values) - 1

        def memoize(key, fn):
            # one evaluation per distinct (node, region) request per call —
            # mirrors the eager pull's request cache (and keeps persistent
            # accumulation from double-counting diamond fan-in)
            def run(arrays, origins, ctx, _key=key, _fn=fn):
                if _key in ctx["memo"]:
                    return ctx["memo"][_key]
                out = _fn(arrays, origins, ctx)
                ctx["memo"][_key] = out
                return out

            return run

        def build(
            n: ProcessObject, region: ImageRegion, in_window: bool = False
        ) -> Optional[Callable]:
            key = (id(n), region, in_window)
            if key in built:
                ordinal, fn = built[key]
                sig.append(("ref", ordinal))
                return fn
            ordinal = len(built)
            own_info = infos[id(n)]
            clamped = clamp(region, own_info)
            # boundary-pad widths are baked into the trace → part of the key
            pads = (
                clamped.row0 - region.row0,
                region.row1 - clamped.row1,
                clamped.col0 - region.col0,
                region.col1 - clamped.col1,
            )
            ups = self._inputs[id(n)]
            if not ups:
                if in_window:
                    # a windowed read's clamped rect is read-stage-only (the
                    # delivered array is always padded to the full window),
                    # so it is WALK-MODE-INDEPENDENT: rows pass through (the
                    # read stage's snap handles fully-virtual rows), columns
                    # clamp in-image (window_request anchors windows
                    # in-image) — real and virtual describes of one window
                    # record identical reads
                    c0 = max(region.col0, 0)
                    c1 = max(c0, min(region.col1, own_info.cols))
                    clamped = ImageRegion(
                        (region.row0, c0), (region.rows, c1 - c0)
                    )
                # non-windowed reads dedup on the clamped rect alone (the
                # per-consumer spill pad is baked in the trace); windowed
                # reads pad to their window at the read stage, so the window
                # region is part of their identity
                k = (
                    (id(n), clamped, region, True)
                    if in_window
                    else (id(n), clamped)
                )
                if k not in read_index:
                    read_index[k] = len(reads)
                    reads.append((n, clamped, region))  # type: ignore[arg-type]
                    read_windows.append(region.size if in_window else None)
                idx = read_index[k]
                # tiled/range-readable sources stamp their storage geometry
                # (tile size, overview level) into the read record — see
                # Source.read_record
                rrec = n.read_record()
                if in_window:
                    # windowed read: static window shape, no pads in the
                    # trace — border spill is materialized at the READ stage
                    # (host boundary_pad / SPMD halo replication), so border
                    # regions share the interior signature
                    sig.append(("wread", n._serial, idx, region.size,
                                np.dtype(own_info.dtype).str, own_info.bands,
                                rrec))
                else:
                    sig.append(("read", n._serial, idx, clamped.size, pads,
                                np.dtype(own_info.dtype).str, own_info.bands,
                                rrec))
                fn = None
                if lower:
                    if in_window:

                        def run_source(arrays, origins, ctx, _idx=idx):
                            return arrays[_idx]

                    else:

                        def run_source(arrays, origins, ctx, _idx=idx,
                                       _clamped=clamped, _region=region):
                            return boundary_pad(arrays[_idx], _clamped, _region)

                    fn = memoize(key, run_source)
                built[key] = (ordinal, fn)
                return fn

            in_infos = [infos[id(u)] for u in ups]
            reqs = n.requested_region(clamped, *in_infos)
            # window classification: a needs_origin node's drifting requests
            # become conservative static-shape windows (traced origins), so
            # every same-size region lowers to ONE shared trace
            reqs, wbounds = windowed_requests(n, clamped.size, reqs, in_infos)
            origin_aware = bool(getattr(n, "needs_origin", False))
            persist = isinstance(n, PersistentFilter)
            # Pallas fast path: decided identically in describe AND lower
            # (lower_pull re-asserts signature equality).  Origin-aware and
            # persistent nodes keep the generic lowering — their traced
            # scalars / state threading stay outside kernel bodies.
            pallas_on = not origin_aware and not persist and n.pallas_plan()
            if pallas_on:
                fusions = [fuse_chain(u, r) for u, r in zip(ups, reqs)]
                child_fns = [
                    build(deep, dreq, in_window) for _, deep, dreq in fusions
                ]
            else:
                child_fns = [
                    build(u, r, in_window or wb is not None)
                    for u, r, wb in zip(ups, reqs, wbounds)
                ]
            if persist and n not in persistent:
                persistent.append(n)
            oi = (dyn(clamped.row0), dyn(clamped.col0)) if origin_aware else None
            ii = (
                tuple((dyn(r.row0), dyn(r.col0)) for r in reqs)
                if origin_aware
                else None
            )
            # mask-aware persistent filters always thread their absolute
            # (row, col) origin as traced scalars: the in-trace 2-D validity
            # mask is all-true on real geometry and masks virtual pad
            # rows/cols under padded SPMD tiles — one registry body serves
            # both (slot registration must not depend on the walk mode, or
            # real/virtual plans with equal signatures would disagree on the
            # origin vector length)
            mi = (
                (dyn(clamped.row0), dyn(clamped.col0))
                if persist and n.supports_mask
                else None
            )
            winb = wbounds if any(b is not None for b in wbounds) else None
            if pallas_on:
                # fused chain nodes contribute no records of their own; the
                # kernel's record carries their serials, so fused and unfused
                # plans of one graph can never share a registry entry
                fused = tuple(
                    tuple(c._serial for c, _ in chain) for chain, _, _ in fusions
                )
                sig.append(
                    ("pallas", n._serial, clamped.size, pads,
                     n.plan_key(clamped), fused)
                )
                pallas_serials.append(n._serial)
                for chain, _, _ in fusions:
                    fused_serials.extend(c._serial for c, _ in chain)
            else:
                sig.append(
                    ("node", n._serial, clamped.size, pads, origin_aware,
                     persist, n.plan_key(clamped), winb)
                )
            fn = None
            if lower and pallas_on:
                pre_fns: List[Optional[Callable]] = []
                for chain, _, _ in fusions:
                    if not chain:
                        pre_fns.append(None)
                        continue
                    chain_fns = tuple(f for _, f in chain)

                    def composed(t, _fns=chain_fns):
                        # chain[0] sits nearest the kernel: apply deepest-first
                        for g in reversed(_fns):
                            t = g(t)
                        return t

                    pre_fns.append(composed)
                body = n.pallas_body(tuple(pre_fns))

                def run_pallas(arrays, origins, ctx, _body=body,
                               _clamped=clamped, _region=region,
                               _fns=child_fns):
                    ins = [f(arrays, origins, ctx) for f in _fns]
                    return boundary_pad(_body(*ins), _clamped, _region)

                fn = memoize(key, run_pallas)
            elif lower:

                def run_node(arrays, origins, ctx, _n=n, _clamped=clamped,
                             _region=region, _fns=child_fns, _oi=oi, _ii=ii,
                             _persist=persist, _mi=mi,
                             _rows_total=own_info.rows,
                             _cols_total=own_info.cols):
                    ins = [f(arrays, origins, ctx) for f in _fns]
                    if _persist:
                        if _mi is not None:
                            rows_abs = origins[_mi[0]] + jnp.arange(_clamped.rows)
                            cols_abs = origins[_mi[1]] + jnp.arange(_clamped.cols)
                            rv = (rows_abs >= 0) & (rows_abs < _rows_total)
                            cv = (cols_abs >= 0) & (cols_abs < _cols_total)
                            mask = rv[:, None, None] & cv[None, :, None]
                            ctx["pstates"][_n.name] = _n.accumulate(
                                ctx["pstates"][_n.name], _clamped, *ins,
                                mask=mask,
                            )
                        else:
                            ctx["pstates"][_n.name] = _n.accumulate(
                                ctx["pstates"][_n.name], _clamped, *ins
                            )
                    if _oi is not None:
                        out = _n.generate(
                            _clamped,
                            *ins,
                            origin=(origins[_oi[0]], origins[_oi[1]]),
                            input_origins=tuple(
                                (origins[a], origins[b]) for a, b in _ii
                            ),
                        )
                    else:
                        out = _n.generate(_clamped, *ins)
                    return boundary_pad(out, _clamped, _region)

                fn = memoize(key, run_node)
            built[key] = (ordinal, fn)
            return fn

        root = build(node, out_region)
        persistent_nodes = list(persistent)
        static_origins = tuple(origin_values)

        if not lower:
            return PlanDescription(
                node=node,
                out_region=out_region,
                reads=reads,
                signature=tuple(sig),
                origin_values=static_origins,
                persistent_nodes=persistent_nodes,
                windows=tuple(read_windows),
                virtual=virtual,
                pad_rows=(
                    max(0, out_region.row1 - infos[id(node)].rows)
                    if virtual
                    else 0
                ),
                pad_cols=(
                    max(0, out_region.col1 - infos[id(node)].cols)
                    if virtual == "grid"
                    else 0
                ),
                pallas_nodes=tuple(pallas_serials),
                fused_nodes=tuple(fused_serials),
            )

        def canonical_fn(arrays, pstates, origins):
            ctx = {"pstates": dict(pstates), "memo": {}}
            out = root(arrays, origins, ctx)
            return out, ctx["pstates"]

        def legacy_fn(arrays, _origins=static_origins):
            # seed-compatible entry point: origins baked in as constants
            states = {p.name: p.reset() for p in persistent_nodes}
            out, _ = canonical_fn(arrays, states, _origins)
            return out

        return PullPlan(
            reads=reads,
            fn=legacy_fn,
            out_region=out_region,
            canonical_fn=canonical_fn,
            signature=tuple(sig),
            origin_values=static_origins,
            persistent_nodes=persistent_nodes,
            windows=tuple(read_windows),
            pallas_nodes=tuple(pallas_serials),
            fused_nodes=tuple(fused_serials),
        )


@dataclasses.dataclass
class PullPlan:
    """``reads``: list of (source, clamped_region, requested_region);
    ``fn(arrays)`` with arrays[i] covering reads[i]'s clamped region returns
    the output pixels (origins baked in — the seed-compatible entry point).

    ``canonical_fn(arrays, pstates, origins)`` is the cacheable form:
    ``origins`` carries the absolute coordinates consumed by ``needs_origin``
    nodes as traced scalars and ``pstates`` threads persistent-filter state,
    so one jit of ``canonical_fn`` serves every region whose ``signature``
    matches this plan's."""

    reads: List[Tuple[Source, ImageRegion, ImageRegion]]
    fn: Callable[[Sequence[jnp.ndarray]], jnp.ndarray]
    out_region: ImageRegion
    canonical_fn: Optional[Callable] = None
    signature: Tuple = ()
    origin_values: Tuple[int, ...] = ()
    persistent_nodes: List[PersistentFilter] = dataclasses.field(
        default_factory=list
    )
    #: per read, the static (rows, cols) window-spec shape for windowed reads
    #: (``needs_origin`` bounding windows), or None for exact covariant reads
    windows: Tuple[Optional[Tuple[int, int]], ...] = ()
    #: serials of nodes lowered to fused Pallas bodies / of pointwise nodes
    #: folded into one (diagnostic mirrors of the signature's pallas records)
    pallas_nodes: Tuple[int, ...] = ()
    fused_nodes: Tuple[int, ...] = ()

    def read_sources(self) -> List[jnp.ndarray]:
        return read_plan_sources(self.reads, self.windows)

    def origins(self) -> Tuple[np.int32, ...]:
        """Per-region dynamic origin scalars, in canonical slot order.  Passed
        as arrays so jit traces (not bakes) them."""
        return tuple(np.int32(v) for v in self.origin_values)

    def initial_pstates(self) -> Dict[str, Dict[str, jnp.ndarray]]:
        return {p.name: p.reset() for p in self.persistent_nodes}

    def run(self) -> jnp.ndarray:
        return self.fn(self.read_sources())
