"""Core pipeline framework — the paper's contribution in JAX.

Exports the region algebra, process-object protocol, pipeline DAG, splitting
strategies, streaming executor, and the shard_map cluster executor.
"""
from repro.core.region import ImageRegion, whole
from repro.core.execplan import (
    CacheStats,
    PlanCache,
    PlanDescription,
    global_plan_cache,
)
from repro.core.process_object import (
    GeoTransform,
    ImageInfo,
    Source,
    Filter,
    PersistentFilter,
    Mapper,
    ProcessObject,
    Reduction,
    boundary_pad,
)
from repro.core.pipeline import Pipeline, PullPlan
from repro.core.splitting import (
    RowCoverage,
    Splitter,
    StripeSplitter,
    TileSplitter,
    AutoSplitter,
    VMEMTileSplitter,
    padded_tile_grid,
    virtual_tile_regions,
)
from repro.core.scheduling import (
    static_schedule,
    cost_weighted_static_schedule,
    lpt_schedule,
    work_stealing_schedule,
    FifoQueue,
    WorkStealingQueue,
    makespan,
)
from repro.core.dag import (
    EdgeFanout,
    EdgeQueue,
    EdgeStats,
    PipelineCancelled,
    RegionGate,
    UpstreamFailed,
)
from repro.core.streaming import (
    BatchedRegionPuller,
    StreamingExecutor,
    StreamResult,
    execute,
    run_pool,
)
from repro.core.orchestrator import Orchestrator, Stage, StageResult
from repro.core.parallel import (
    ParallelExecutor,
    NotStripParallelizable,
    NotTileParallelizable,
    build_strip_plan,
    build_tile_plan,
    halo_exchange_cols,
    halo_exchange_rows,
)

__all__ = [
    "ImageRegion",
    "whole",
    "GeoTransform",
    "ImageInfo",
    "Source",
    "Filter",
    "PersistentFilter",
    "Mapper",
    "ProcessObject",
    "Reduction",
    "boundary_pad",
    "Pipeline",
    "PullPlan",
    "RowCoverage",
    "Splitter",
    "StripeSplitter",
    "TileSplitter",
    "AutoSplitter",
    "VMEMTileSplitter",
    "static_schedule",
    "cost_weighted_static_schedule",
    "lpt_schedule",
    "work_stealing_schedule",
    "FifoQueue",
    "WorkStealingQueue",
    "makespan",
    "EdgeFanout",
    "EdgeQueue",
    "EdgeStats",
    "PipelineCancelled",
    "RegionGate",
    "UpstreamFailed",
    "CacheStats",
    "PlanCache",
    "PlanDescription",
    "global_plan_cache",
    "BatchedRegionPuller",
    "StreamingExecutor",
    "StreamResult",
    "execute",
    "run_pool",
    "Orchestrator",
    "Stage",
    "StageResult",
    "ParallelExecutor",
    "NotStripParallelizable",
    "NotTileParallelizable",
    "build_strip_plan",
    "build_tile_plan",
    "halo_exchange_cols",
    "halo_exchange_rows",
    "padded_tile_grid",
    "virtual_tile_regions",
]
