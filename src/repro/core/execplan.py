"""The ExecutionPlan layer: one compiled-plan registry for every executor.

The paper's framework promises that *any* pipeline runs on *any* cluster
layout transparently; the executors must therefore agree on what a compiled
plan *is*.  This module owns that contract:

  * :class:`PlanDescription` — the result of the cheap *describe* pass
    (``Pipeline.describe_pull``): the set of source reads, the canonical plan
    signature, the dynamic origin scalars and the persistent nodes for one
    (node, region) request.  Building it costs one host-side graph walk and
    **no** closure construction — it is run once per region, on every region.
  * :class:`PlanCache` — the process-shareable compiled-plan registry, keyed
    by canonical signature.  The *lower* pass (``Pipeline.lower_pull``, which
    builds the jittable closure tree) runs only on registry misses; hits are
    describe-pass-only.  Both :class:`~repro.core.streaming.StreamingExecutor`
    and :class:`~repro.core.parallel.ParallelExecutor` consult one registry,
    so a pipeline traced by one executor is a cache *hit* for the other on
    matching strip geometry.
  * :func:`global_plan_cache` — the process-wide default registry
    (LRU-bounded), used by the orchestrator so stages mixing streaming and
    SPMD workers share compiled plans.

Plan signatures embed per-node *serial numbers* (monotonic construction
counters, see :class:`~repro.core.process_object.ProcessObject`) rather than
``id()`` values, so a process-wide registry can never confuse a dead
pipeline's recycled object ids with a live one's.

Plan lifecycle — every executor follows the same steps::

      (node, region)
            │ tile-grid probe   SPMD only: build_tile_plan describes EVERY
            ▼                   virtual tile of the nr × nc padded grid
      virtual tile geometry     (virtual_tile_regions; 1-D strips are the
            │                   nc = 1 column) and demands one shared
            │                   interior signature — else
            │                   NotTileParallelizable with diagnostics
            │ describe          Pipeline.describe_pull — one host graph walk:
            ▼                   exact requests of needs_origin nodes become
      PlanDescription           static-shape WINDOW specs (window_bound hook);
            │                   reads/origins recorded, no closures built
            │ fuse              the SAME walk classifies the Pallas fast
            ▼                   path: pallas_plan() nodes become "pallas"
      fusion classification     steps and single-consumer pointwise chains
            │                   feeding them (pointwise_fn) FOLD into the
            │ signature         kernel — fused nodes leave no records
            ▼
      canonical signature       shape/pad/plan-key statics + node serials +
            │ registry lookup   window-spec shapes + pallas/fusion records;
            ▼                   absolute coordinates and window origins stay
      PlanCache.compiled_for    OUT (traced scalars)
            │         │
            │         └── hit ──► _CompiledEntry (reuse, zero lowers)
            ▼ miss
      lower                     Pipeline.lower_pull — closure tree; pallas
            │                   steps lower to pallas_body(pre_fns): ONE
            ▼                   fused Pallas call per tile, the chain's
      PullPlan.canonical_fn     pre_fns applied on VMEM tiles in-kernel
            │                   fn(arrays, pstates, origins) → jit + register
            │ tiled read        read_plan_sources resolves every plan read
            ▼                   through the Source/Sink protocol: flat RTIF
      source arrays             memmap windows, or RTIC tiled reads (tile
                                cover ∩ LRU cache over range requests, with
                                the streaming engine's schedule prefetched
                                async via RasterSource.read_ahead).  Tiled
                                sources stamp tile geometry + overview level
                                into the read records (Source.read_record),
                                so a re-tiled container never aliases a flat
                                source's signature — and a TiledSource plan
                                warmed by one executor is a registry hit for
                                every other, same as flat sources.

Serving request path — the tile-serving front end (:mod:`repro.serve.tiles`)
rides the same lifecycle, one extra registry hop deep::

      TileRequest (pipeline, zoom, x, y)
            │ admission         serve.admission — bounded queue depth,
            ▼                   shed-or-block policy
      (node, tile region)       TileGrid.region(x, y)
            │ describe          the SAME describe pass as above — the
            ▼                   plan signature IS the batch key
      signature group           concurrent requests with equal signatures
            │ batch             coalesce into ONE invocation: arrays and
            ▼                   origin scalars stack along a leading tile
      batched program           axis, jax.vmap(canonical_fn) jits under
            │                   ("serve_batched", signature, bucket) via
            ▼                   get_or_build — post warm-up every hop is
      (tiles, no new traces)    a registry hit: zero lowers, zero compiles

:meth:`PlanCache.warm` is the warm-up protocol: describe a geometry sweep,
lower every distinct signature, and (``execute=True``) run each entry once so
XLA traces before the first live request.  :meth:`PlanCache.stats_snapshot`
freezes the counters as a plain dict — the serving metrics and the perf
benches diff two snapshots instead of reaching into live counters.

Windowed reads make this lifecycle *total* over P1–P7: a warp's drifting
request is classified at describe time as a conservative static bounding
window (rows anchored at the request origin, columns shifted in-image), so
interior regions of one size share one signature, the streaming engine
prefetches fixed-shape windows, and the SPMD executor lowers the same entry
to ``lax.dynamic_slice`` of the halo-exchanged shard — one trace per
geometry signature on every engine.

Virtual padded tiles make it total over *arbitrary tile-grid geometry*: the
describe pass can run against a virtually padded image
(``describe_pull(..., virtual=True)`` — the ``"grid"`` mode, no clamping in
either axis; ``virtual="rows"`` keeps the restricted rows-only walk for
pipelines whose column borders are not virtualization-safe), so the ragged
edge tiles of an uneven ``nr × nc`` split — and both border strips of an
n=2 halo split — describe exactly like interior tiles and *share the
interior signature*.  The resulting :class:`PlanDescription` carries the pad
metadata (the ``virtual`` mode + ``pad_rows``/``pad_cols``, the trailing
output rows/cols beyond the real image) OUTSIDE the signature: registry
lookup still lands on the one interior entry, the read stage materializes
the spilled rows/cols by edge replication (:func:`read_plan_sources`
host-side, halo replication of the edge-padded global under SPMD),
mask-aware persistent filters accumulate under an in-trace 2-D validity mask
derived from their traced (row, col) origin, and the executor crops the pad
before the write stage.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.process_object import boundary_pad
from repro.core.region import ImageRegion

if TYPE_CHECKING:  # pragma: no cover — typing only, avoids an import cycle
    from repro.core.pipeline import PullPlan
    from repro.core.process_object import PersistentFilter, ProcessObject, Source


@dataclasses.dataclass
class CacheStats:
    """Counters for one :class:`PlanCache`.

    ``compiles`` counts actual jax traces of registry entries (incremented
    from inside the traced body, so a value of 1 proves a whole run retraced
    exactly once).  ``lowers`` counts closure-tree constructions (lower
    passes) — on the describe-pass path a cache hit performs zero of either.
    """

    compiles: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    lowers: int = 0

    def snapshot(self) -> Dict[str, int]:
        """The counters frozen as a plain dict — the live object keeps
        counting, the snapshot does not.  Consumers that need a before/after
        delta (serving metrics, bench gates) diff two snapshots instead of
        caching references into live counters."""
        return {
            "compiles": self.compiles,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "lowers": self.lowers,
        }


def read_plan_sources(reads, windows) -> List:
    """Materialize a plan's source reads (shared by :class:`PlanDescription`
    and :class:`~repro.core.pipeline.PullPlan`).  Windowed reads are
    delivered at the full static window shape — the trace carries no pads
    for them, so border spill is edge-replicated here, at the read stage.

    The read stage is *total over virtual geometry* in both axes: a read
    whose region spills past the source's real rows **or columns** (virtual
    padded tiles) is clamped to the image and edge-replicated back out — the
    host-side twin of the SPMD executor's edge-padded-global + row/column
    halo replication, so a virtual plan's inputs carry the same pixel values
    on every engine.

    An empty ``windows`` means "no windowed reads" (plans built before the
    describe pass existed); a non-empty tuple must align with ``reads``.
    """
    if windows and len(windows) != len(reads):
        raise ValueError(
            f"windows/reads misaligned: {len(windows)} window specs for "
            f"{len(reads)} reads"
        )
    def snap(lo: int, hi: int, n: int):
        """Per axis: the in-image read range and the edge pads placing it
        back inside half-open [lo, hi).  The range is the overlap with
        [0, n) when one exists; on a fully-virtual axis it is the nearest
        single edge unit and every output unit replicates it (one-sided pad
        — the single source value makes the split immaterial)."""
        a, b = max(lo, 0), min(hi, n)
        if a < b:
            return a, b, (a - lo, hi - b)
        if hi <= 0:  # entirely above/left of the image: replicate unit 0
            return 0, 1, ((hi - lo) - 1, 0)
        return n - 1, n, (0, (hi - lo) - 1)  # entirely below/right

    wins = windows if windows else (None,) * len(reads)
    out = []
    for (s, clamped, region), w in zip(reads, wins):
        full = s.output_info().full_region
        have = clamped.clamp(full)
        if not have.is_empty():
            arr = boundary_pad(s.generate(have), have, clamped)
        else:
            # the region misses the image entirely on >= 1 axis (a strip
            # fully past the border, e.g. more workers than rows): read the
            # nearest edge unit on the virtual axis and replicate outward —
            # pure edge extension, the exact values the SPMD padded global
            # holds over its pad rows
            r0, r1, rpad = snap(clamped.row0, clamped.row1, full.rows)
            c0, c1, cpad = snap(clamped.col0, clamped.col1, full.cols)
            arr = np.asarray(s.generate(ImageRegion((r0, c0), (r1 - r0, c1 - c0))))
            arr = np.pad(
                arr, [rpad, cpad] + [(0, 0)] * (arr.ndim - 2), mode="edge"
            )
        if w is not None:
            arr = boundary_pad(arr, clamped, region)
        out.append(arr)
    return out


@dataclasses.dataclass
class PlanDescription:
    """Output of the describe pass: everything the registry and the read
    stage need, with no compiled closure attached.

    ``reads``: list of (source, clamped_region, requested_region) in plan
    order; ``signature`` is the canonical plan key (shape/boundary/plan-key
    static data, per-node serials); ``origin_values`` are this region's
    absolute coordinates for ``needs_origin`` nodes — and the absolute row
    origins of mask-aware persistent filters — threaded into the compiled
    function as traced scalars.  ``windows[i]`` is the static (rows, cols)
    window-spec shape when read *i* is a windowed read (the request of a
    ``needs_origin`` node lowered to a fixed-shape bounding window whose
    origin is traced), else None.

    Pad metadata: ``virtual`` carries the virtual-describe mode the walk ran
    in (``False`` for the exact walk, ``"grid"`` for the fully unclamped 2-D
    walk, ``"rows"`` for the restricted rows-only walk — a tile spilling
    past the image shares the interior signature), and ``pad_rows`` /
    ``pad_cols`` count the trailing output rows/cols that lie beyond the
    real image (0 on real geometry).  None of these is part of the
    signature — that is the point: a virtual tile's plan *is* the interior
    plan, and the executor crops/masks the pad instead.
    """

    node: "ProcessObject"
    out_region: "ImageRegion"
    reads: List[Tuple["Source", "ImageRegion", "ImageRegion"]]
    signature: Tuple
    origin_values: Tuple[int, ...]
    persistent_nodes: List["PersistentFilter"]
    windows: Tuple[Optional[Tuple[int, int]], ...] = ()
    virtual: "bool | str" = False
    pad_rows: int = 0
    pad_cols: int = 0
    #: serials of nodes the plan lowers to fused Pallas bodies, and of the
    #: pointwise nodes folded into one — diagnostic mirrors of the
    #: signature's ``("pallas", ...)`` records (empty on jnp-only plans)
    pallas_nodes: Tuple[int, ...] = ()
    fused_nodes: Tuple[int, ...] = ()

    def read_sources(self) -> List:
        return read_plan_sources(self.reads, self.windows)

    def origins(self) -> Tuple[np.int32, ...]:
        """Per-region dynamic origin scalars, in canonical slot order.  Passed
        as arrays so jit traces (not bakes) them."""
        return tuple(np.int32(v) for v in self.origin_values)

    def initial_pstates(self) -> Dict[str, Dict]:
        return {p.name: p.reset() for p in self.persistent_nodes}


class _CompiledEntry:
    """One jitted canonical function.  The first call is serialized so
    concurrent pool workers can't race XLA into tracing the same signature
    twice; afterwards calls are lock-free.  ``canonical_fn`` stays reachable
    so the SPMD executor can trace the very same closure into its shard_map
    program instead of rebuilding it."""

    def __init__(self, canonical_fn: Callable, stats: CacheStats):
        self.canonical_fn = canonical_fn

        def counted(arrays, pstates, origins):
            stats.compiles += 1  # executes at trace time only
            return canonical_fn(arrays, pstates, origins)

        self._jitted = jax.jit(counted)
        self._lock = threading.Lock()
        self._primed = False

    def __call__(self, arrays, pstates, origins):
        if not self._primed:
            with self._lock:
                out = self._jitted(arrays, pstates, origins)
                self._primed = True
                return out
        return self._jitted(arrays, pstates, origins)


class PlanCache:
    """Compiled-plan registry keyed by canonical plan signature.

    Shareable across executors / pool workers / orchestrator stages (all
    methods are thread-safe).  ``max_entries`` bounds the registry with LRU
    eviction; evicted entries recompile on next use (counted in stats).

    Besides per-region pull plans the registry also holds whole executor
    programs (e.g. a jitted shard_map SPMD program) via :meth:`get_or_build`,
    so repeated :class:`~repro.core.parallel.ParallelExecutor` runs on the
    same pipeline/geometry reuse one program.
    """

    def __init__(self, max_entries: Optional[int] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.stats = CacheStats()
        self._entries: "collections.OrderedDict[Tuple, object]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._entries)

    def _store(self, key, value):
        self._entries[key] = value
        if self.max_entries is not None and len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def compiled(self, plan: "PullPlan") -> _CompiledEntry:
        """The compiled function for an already-lowered ``plan`` (the legacy
        entry point: the caller paid the closure build regardless of hit or
        miss).  Plans with equal signatures share one entry."""
        key = plan.signature
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return entry
            self.stats.misses += 1
            self.stats.lowers += 1  # the caller lowered eagerly for this miss
            entry = _CompiledEntry(plan.canonical_fn, self.stats)
            self._store(key, entry)
            return entry

    def compiled_for(
        self, desc: PlanDescription, lower: Callable[[], "PullPlan"]
    ) -> _CompiledEntry:
        """The compiled function for ``desc``'s signature.  On a hit the
        closure tree is **not** rebuilt — ``lower`` runs only on misses, and
        *outside* the registry lock so a miss never stalls other workers'
        hits (two workers racing the same cold signature may both lower; the
        first insert wins and only it is counted — XLA tracing is still
        deduplicated by the entry's own priming lock)."""
        key = desc.signature
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return entry
        plan = lower()
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:  # lost the race: the peer's lower won
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return entry
            self.stats.misses += 1
            self.stats.lowers += 1
            entry = _CompiledEntry(plan.canonical_fn, self.stats)
            self._store(key, entry)
            return entry

    def stats_snapshot(self) -> Dict[str, int]:
        """The registry counters as a plain dict (see
        :meth:`CacheStats.snapshot`).  This is the supported way to read the
        counters for metrics/benchmarks — ``StreamResult.cache_snapshot`` and
        the serving engine's ``metrics()`` both surface exactly this."""
        return self.stats.snapshot()

    def warm(
        self,
        pipeline,
        node,
        regions,
        virtual: "bool | str" = False,
        execute: bool = True,
    ) -> int:
        """Warm-up protocol: describe every region of a geometry sweep, lower
        each *distinct* signature into the registry, and (``execute=True``)
        run each entry once so XLA traces now rather than on the first live
        request.  Returns the number of distinct signatures ensured.

        ``pipeline``/``node`` follow the ``Pipeline.describe_pull`` protocol;
        ``virtual`` selects the virtually padded describe walk (``"grid"`` /
        ``"rows"`` / ``False`` — callers should pass
        ``Pipeline.virtual_describe_mode()``, the same mode their
        serving/streaming path will use, or the warmed signatures won't be
        the ones the live path looks up).
        """
        seen = set()
        for region in regions:
            desc = pipeline.describe_pull(node, region, virtual=virtual)
            if desc.signature in seen:
                continue
            seen.add(desc.signature)
            entry = self.compiled_for(desc, lambda: pipeline.lower_pull(desc))
            if execute:
                out, _ = entry(
                    desc.read_sources(), desc.initial_pstates(), desc.origins()
                )
                jax.block_until_ready(out)
        return len(seen)

    def get_or_build(self, key: Tuple, build: Callable[[], object]):
        """Generic registry slot for executor-level programs (keyed by the
        caller; e.g. a jitted SPMD program under its geometry signature).
        ``build`` runs outside the lock; the first insert wins."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return entry
        built = build()
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return entry
            self.stats.misses += 1
            self._store(key, built)
            return built


_GLOBAL_LOCK = threading.Lock()
_GLOBAL_CACHE: Optional[PlanCache] = None


def global_plan_cache() -> PlanCache:
    """The process-wide compiled-plan registry (LRU-bounded).

    Executors accept any :class:`PlanCache`; this is the canonical shared one
    — the orchestrator and :func:`repro.pipelines.run_pipeline` default to
    it, so streaming, pool and SPMD runs in one process share compiled plans.
    """
    global _GLOBAL_CACHE
    with _GLOBAL_LOCK:
        if _GLOBAL_CACHE is None:
            _GLOBAL_CACHE = PlanCache(max_entries=512)
        return _GLOBAL_CACHE


def reset_global_plan_cache() -> PlanCache:
    """Swap in a fresh process-wide registry and return the **old** one.

    The old cache object (and its :class:`CacheStats`) stays fully usable:
    executors that captured it — e.g. a ``StreamResult.cache_stats`` from an
    earlier run — keep reading their own counters (evictions included), so a
    reset never zeroes history out from under a caller.  Subsequent
    :func:`global_plan_cache` calls see an empty registry with fresh
    counters."""
    global _GLOBAL_CACHE
    with _GLOBAL_LOCK:
        old = _GLOBAL_CACHE if _GLOBAL_CACHE is not None else PlanCache(max_entries=512)
        _GLOBAL_CACHE = PlanCache(max_entries=512)
        return old
