"""Region-granularity DAG scheduling: bounded edge queues + commit gates.

The barrier orchestrator runs stages sequentially with a fully materialized
intermediate between every pair, so a multi-stage job pays the *sum* of stage
wall times.  This module provides the machinery that lets connected stages
stream into each other at **region granularity** (the paper's §IV.C
"orchestration of multiple connected pipelines", at the granularity the
workflow-design studies in PAPERS.md found decisive for satellite imagery):

  * :class:`EdgeQueue` — one producer→consumer edge.  The producer's
    write-behind reports **committed** row extents (rows whose bytes a
    ``pwrite``/flush actually put on disk — not rows merely buffered in the
    :class:`~repro.raster.io.StripWriter` coalescing run); the consumer
    derives per-region readiness from the committed coverage
    (:class:`~repro.core.splitting.RowCoverage`).  A bounded number of
    committed-but-unreleased strips (``capacity``) applies backpressure to
    the producer, and failures propagate in both directions instead of
    wedging either side.
  * :class:`EdgeFanout` — the producer-side sink a writer mapper binds to:
    it fans ``offer`` (flow control, before the write) and ``commit`` (after
    the bytes are on disk) out to every outgoing edge.
  * :class:`RegionGate` — the consumer-side gate the streaming executors
    accept: given a region's :class:`~repro.core.execplan.PlanDescription`
    it blocks until the **exact input rows the region reads** (halos and
    windowed reads included — the describe pass records them) are committed
    upstream, and releases them when the region completes.

Deadlock freedom
----------------

Backpressure yields to *unmet demand*: a producer blocked at ``capacity``
proceeds (counted as an ``overdraft``) exactly while some consumer is
blocked waiting for rows **no offered strip covers** — rows the queue's
in-flight strips cannot possibly satisfy, e.g. a halo read past the
frontier at ``capacity=1``, or a whole-image consumer region.  A consumer
blocked on rows that *are* offered needs no overdraft: offered strips are
written unconditionally once their offer returns, and the waiting consumer
re-runs the producer writer's flush on every poll, so buffered-but-
uncommitted coalesced rows always reach disk without producer progress.
Backpressure only engages while a region-granular consumer is attached
(``consumer_started`` — the pipelined orchestrator arms it at edge creation
for pool consumers and never for stage-granularity SPMD consumers).  A
blocked producer therefore always implies a consumer that is processing
ready regions and will release capacity, and a blocked consumer either
drains committed/offered rows via flush or lifts the producer past the
bound — there is no cycle.  Waits additionally poll with a short timeout as
a belt-and-braces guard, and every failure path wakes all sleepers.

When the producer offers strips in consumer (row) order — the pipelined
orchestrator forces FIFO hand-out on producer stages for exactly this
reason — overdrafts stay rare (zero for halo-free graphs at
``capacity >= 2``) and ``max_in_flight`` stays at ``capacity``; an
out-of-order producer keeps liveness but may transiently exceed the bound
while a demanded row waits for its strip to be offered.

Failure propagation
-------------------

A failed producer marks its outgoing edges with the original exception;
blocked consumers raise :class:`UpstreamFailed` carrying that original
exception (``.cause``) instead of hanging.  A global cancel (a failed
sibling stage, or :meth:`Orchestrator.cancel`) marks every edge with
:class:`PipelineCancelled`; blocked producers and consumers alike unwind
promptly.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.execplan import PlanDescription
from repro.core.region import ImageRegion
from repro.core.splitting import RowCoverage

#: belt-and-braces poll period for blocked waits — all state transitions
#: notify the condition, so this only bounds the damage of a missed wakeup
_POLL_S = 0.1


class PipelineCancelled(RuntimeError):
    """The pipelined run was aborted (failed sibling stage or user cancel)."""


class UpstreamFailed(RuntimeError):
    """A producer stage failed; its consumers cancel with the original error.

    ``stage`` names the failed producer and ``cause`` is the original
    exception (never another :class:`UpstreamFailed` — nesting is unwrapped
    at raise time, so a chain failure surfaces the root cause everywhere).
    """

    def __init__(self, stage: str, cause: BaseException):
        while isinstance(cause, UpstreamFailed):
            stage, cause = cause.stage, cause.cause
        super().__init__(f"upstream stage {stage!r} failed: {cause!r}")
        self.stage = stage
        self.cause = cause


@dataclasses.dataclass
class EdgeStats:
    """Counters for one edge of a pipelined run.

    ``max_in_flight`` is the peak number of producer strips offered but not
    yet released by the consumer — the bound the queue capacity enforces
    while a region-granular consumer is attached.  ``overdrafts`` counts
    offers that proceeded past capacity because a consumer was blocked
    waiting for rows no offered strip covers (unmet demand overrides pacing
    — see the module docstring's deadlock-freedom argument).
    """

    commits: int = 0
    offers: int = 0
    waits: int = 0
    releases: int = 0
    overdrafts: int = 0
    max_in_flight: int = 0


class EdgeQueue:
    """Bounded region queue on one producer→consumer stage edge."""

    def __init__(self, producer: str, consumer: str, capacity: int = 2):
        if capacity < 1:
            raise ValueError("queue capacity must be >= 1")
        self.producer = producer
        self.consumer = consumer
        self.capacity = capacity
        self.stats = EdgeStats()
        self._cv = threading.Condition()
        self._rows: Optional[int] = None  # total output rows, set at open
        self._committed = RowCoverage()
        self._offered = RowCoverage()  # rows whose offer returned (write follows)
        self._released = RowCoverage()
        #: offered-but-unreleased strips, FIFO by offer order
        self._tokens: "collections.deque[Tuple[int, int]]" = collections.deque()
        self._opened = False
        self._producer_done = False
        self._consumer_active = False  # a region-granular consumer is pulling
        self._consumer_done = False
        self._failure: Optional[BaseException] = None
        self._failed_stage: Optional[str] = None  # None → global cancel
        self._flush_cb: Optional[Callable[[], None]] = None
        #: row ranges consumers are currently blocked on in wait_rows
        self._wait_demands: List[List[int]] = []

    # -- failure/cancel (either side, or the orchestrator) ---------------------
    def fail(self, stage: str, exc: BaseException) -> None:
        """Mark the edge failed by ``stage`` (the producer); wake everyone."""
        with self._cv:
            if self._failure is None:
                self._failure, self._failed_stage = exc, stage
            self._cv.notify_all()

    def cancel(self, exc: BaseException) -> None:
        """Global abort: wake everyone with :class:`PipelineCancelled`.  An
        edge already failed keeps its more specific producer failure."""
        with self._cv:
            if self._failure is None:
                self._failure, self._failed_stage = exc, None
            self._cv.notify_all()

    def _raise_if_failed_locked(self) -> None:
        if self._failure is None:
            return
        if self._failed_stage is not None:
            raise UpstreamFailed(self._failed_stage, self._failure)
        raise PipelineCancelled(
            f"edge {self.producer!r}→{self.consumer!r} cancelled"
        ) from self._failure

    # -- producer side ---------------------------------------------------------
    def open(self, rows: int) -> None:
        """The producer's output file exists (header written): consumers may
        build their readers now."""
        with self._cv:
            self._rows = int(rows)
            self._opened = True
            self._cv.notify_all()

    def set_flush(self, cb: Callable[[], None]) -> None:
        """Register the producer writer's flush so a waiting consumer can
        force buffered-but-uncommitted coalesced rows onto disk."""
        with self._cv:
            self._flush_cb = cb

    def _unmet_demand_locked(self) -> bool:
        """True when a blocked consumer demands rows no offered strip covers
        — rows the in-flight window cannot satisfy without this (or a later)
        offer proceeding."""
        return any(
            not self._offered.covers(lo, hi) for lo, hi in self._wait_demands
        )

    def offer(self, region: ImageRegion) -> None:
        """Flow control, called by the producer *before* writing ``region``.

        Blocks while ``capacity`` strips are in flight **and** a
        region-granular consumer is attached and making progress on the
        offered rows; a consumer blocked on rows *beyond* every offered
        strip lifts the backpressure (overdraft) so the pipeline can never
        cycle-wait.  Raises when the run was cancelled.
        """
        with self._cv:
            self._raise_if_failed_locked()
            if region.col0 != 0:
                raise ValueError(
                    f"edge {self.producer!r}→{self.consumer!r}: pipelined "
                    "producers must write full-width strips (row-granularity "
                    "commit protocol); got a tile split — use barrier mode "
                    "or a stripe splitter"
                )
            self.stats.offers += 1
            while (
                self._consumer_active
                and not self._consumer_done
                and len(self._tokens) >= self.capacity
                and not self._unmet_demand_locked()
            ):
                self._cv.wait(_POLL_S)
                self._raise_if_failed_locked()
            if (
                self._consumer_active
                and not self._consumer_done
                and len(self._tokens) >= self.capacity
            ):
                self.stats.overdrafts += 1
            self._tokens.append((region.row0, region.row1))
            self._offered.add(region.row0, region.row1)
            self.stats.max_in_flight = max(
                self.stats.max_in_flight, len(self._tokens)
            )
            self._cv.notify_all()  # waiters re-check offered coverage

    def commit(self, row0: int, row1: int) -> None:
        """Rows ``[row0, row1)`` are on disk (called post-``pwrite``/flush by
        the producer's :class:`~repro.raster.io.StripWriter`)."""
        with self._cv:
            self._committed.add(row0, row1)
            self.stats.commits += 1
            self._cv.notify_all()

    def close_producer(self) -> None:
        """The producer stage completed: all rows are committed."""
        with self._cv:
            if self._rows is not None:
                self._committed.add(0, self._rows)
            self._producer_done = True
            self._cv.notify_all()

    # -- consumer side ---------------------------------------------------------
    def wait_open(self, timeout: Optional[float] = None) -> None:
        with self._cv:
            waited = 0.0
            while not self._opened:
                self._raise_if_failed_locked()
                self._cv.wait(_POLL_S)
                waited += _POLL_S
                if timeout is not None and waited >= timeout:
                    raise TimeoutError(
                        f"edge {self.producer!r}→{self.consumer!r}: producer "
                        f"never opened within {timeout}s"
                    )
            self._raise_if_failed_locked()

    def consumer_started(self) -> None:
        """A region-granular consumer is attached: engage backpressure."""
        with self._cv:
            self._consumer_active = True
            self._cv.notify_all()

    def consumer_finished(self) -> None:
        """The consumer stage completed: lift backpressure for good."""
        with self._cv:
            self._consumer_done = True
            self._tokens.clear()
            self._cv.notify_all()

    def wait_rows(self, row0: int, row1: int) -> None:
        """Block until rows ``[row0, row1)`` are committed upstream (clamped
        to the producer's real rows).  Raises :class:`UpstreamFailed` /
        :class:`PipelineCancelled` instead of hanging on a dead producer.

        While blocked, the demand is registered so producer offers covering
        rows beyond the offered frontier can overdraft past capacity, and
        the producer writer's flush is re-run on **every** poll — rows whose
        write landed in the coalescing buffer after our previous flush still
        reach disk without any further producer progress."""
        if self._rows is not None:
            row0, row1 = max(0, row0), min(self._rows, row1)
        if row1 <= row0:
            return
        demand = [row0, row1]
        with self._cv:
            self._raise_if_failed_locked()
            if self._committed.covers(row0, row1):
                return
            self.stats.waits += 1
            self._wait_demands.append(demand)
            self._cv.notify_all()  # wake a producer blocked on backpressure
        try:
            while True:
                # flush OUTSIDE the edge lock: the writer's commit hook runs
                # under the writer lock and takes this edge's lock, so
                # holding it here would invert the lock order
                flush = self._flush_cb
                if flush is not None:
                    try:
                        flush()  # force coalesced-but-unflushed rows to disk
                    except Exception:
                        pass  # advisory only — the writer may be mid-close
                with self._cv:
                    if self._committed.covers(row0, row1):
                        return
                    self._raise_if_failed_locked()
                    if self._producer_done:
                        raise RuntimeError(
                            f"edge {self.producer!r}→{self.consumer!r}: "
                            f"producer completed without committing rows "
                            f"[{row0}, {row1}) — commit hook not wired?"
                        )
                    self._cv.wait(_POLL_S)
                    if self._committed.covers(row0, row1):
                        return
                    self._raise_if_failed_locked()
        finally:
            with self._cv:
                self._wait_demands.remove(demand)
                self._cv.notify_all()

    def release(self, row0: int, row1: int) -> None:
        """The consumer finished a region that read rows ``[row0, row1)``:
        retire covered in-flight strips (frees producer capacity).  Purely a
        pacing signal — the data stays on disk for later overlapping reads."""
        with self._cv:
            self._released.add(row0, row1)
            self.stats.releases += 1
            if self._tokens:
                self._tokens = collections.deque(
                    t for t in self._tokens if not self._released.covers(*t)
                )
            self._cv.notify_all()

    def wait_complete(self, timeout: Optional[float] = None) -> None:
        """Block until the producer stage completed (stage-granularity
        consumers, e.g. an SPMD stage that reads its whole input up front)."""
        with self._cv:
            waited = 0.0
            while not self._producer_done:
                self._raise_if_failed_locked()
                self._cv.wait(_POLL_S)
                waited += _POLL_S
                if timeout is not None and waited >= timeout:
                    raise TimeoutError(
                        f"edge {self.producer!r}→{self.consumer!r}: producer "
                        f"did not complete within {timeout}s"
                    )
            self._raise_if_failed_locked()

    @property
    def in_flight(self) -> int:
        with self._cv:
            return len(self._tokens)


class EdgeFanout:
    """Producer-side sink: fans writer events out to every outgoing edge.

    Bound to the stage's writer mapper
    (:meth:`~repro.raster.mappers.ParallelRasterWriter.bind_commit_sink`):
    ``offer`` applies flow control before each strip write, ``commit`` fires
    from the :class:`~repro.raster.io.StripWriter` commit hook after the
    bytes land on disk, ``opened``/``set_flush`` wire the begin/flush
    lifecycle.
    """

    def __init__(self, edges: Sequence[EdgeQueue]):
        self.edges = list(edges)

    def opened(self, info) -> None:
        for e in self.edges:
            e.open(info.rows)

    def set_flush(self, cb: Callable[[], None]) -> None:
        for e in self.edges:
            e.set_flush(cb)

    def offer(self, region: ImageRegion) -> None:
        for e in self.edges:
            e.offer(region)

    def commit(self, row0: int, row1: int) -> None:
        for e in self.edges:
            e.commit(row0, row1)

    def close(self) -> None:
        for e in self.edges:
            e.close_producer()

    def fail(self, stage: str, exc: BaseException) -> None:
        for e in self.edges:
            e.fail(stage, exc)


class RegionGate:
    """Consumer-side region-availability gate for the streaming executors.

    ``wait(desc)`` blocks until every input row the described region actually
    reads — the describe pass records the exact (halo- and window-inclusive)
    source requests — is committed on its edge; ``done(desc)`` releases those
    rows after the region's output is consumed.  Sources whose ``path`` is
    not a gated edge (side inputs that already exist in full) pass through
    ungated.
    """

    def __init__(self, edges_by_path: Dict[str, EdgeQueue]):
        self.edges_by_path = dict(edges_by_path)

    def _needs(self, desc: PlanDescription) -> List[Tuple[EdgeQueue, int, int]]:
        needs = []
        for source, clamped, _requested in desc.reads:
            edge = self.edges_by_path.get(getattr(source, "path", None))
            if edge is None:
                continue
            full = source.output_info().full_region
            r0 = max(0, clamped.row0)
            r1 = min(full.rows, clamped.row1)
            if r1 > r0:
                needs.append((edge, r0, r1))
        return needs

    def wait(self, desc: PlanDescription) -> None:
        for edge, r0, r1 in self._needs(desc):
            edge.wait_rows(r0, r1)

    def done(self, desc: PlanDescription) -> None:
        for edge, r0, r1 in self._needs(desc):
            edge.release(r0, r1)
