"""internvl2-26b [arXiv:2404.16821] — InternViT frontend + InternLM2 backbone.

Backbone only per the assignment: 48L, d_model=6144, 48H (GQA kv=8,
head_dim 128), d_ff=16384 SwiGLU, vocab=92553.  The InternViT frontend is a
STUB: ``input_specs()`` provides precomputed patch embeddings prepended to
the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    mlp_type="swiglu",
    frontend="vision",
    frontend_tokens=1024,
    tie_embeddings=False,
    train_microbatches=4,
)
