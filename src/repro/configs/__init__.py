"""Architecture registry: ``get_config(arch_id)`` + the assigned shape set."""
from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    cell_is_supported,
    reduced,
)

_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "qwen1.5-0.5b": "qwen15_05b",
    "gemma3-12b": "gemma3_12b",
    "olmo-1b": "olmo_1b",
    "gemma-2b": "gemma_2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "hymba-1.5b": "hymba_1p5b",
    "internvl2-26b": "internvl2_26b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "cell_is_supported",
    "reduced",
    "get_config",
    "ARCH_IDS",
]
