"""olmoe-1b-7b [arXiv:2409.02060] — 64 experts, top-8.

16L, d_model=2048, 16H (kv=16, head_dim 128), expert d_ff=1024, vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    mlp_type="swiglu",
    tie_embeddings=False,
    train_microbatches=2,
)
