"""olmo-1b [arXiv:2402.00838] — non-parametric LayerNorm, no biases.

16L, d_model=2048, 16H (kv=16, head_dim 128), d_ff=8192 SwiGLU, vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparam_ln",
    mlp_type="swiglu",
    tie_embeddings=True,
)
