"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L, d_model=1536, attention-free, ssm_state=128, vocab=50280.
d_inner = 2·d = 3072, head dim P=64 → 48 SSD heads, 1 B/C group.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    norm_type="rmsnorm",
    use_rope=False,
    tie_embeddings=True,
)
