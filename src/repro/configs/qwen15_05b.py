"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B] — dense GQA (kv=16) with QKV bias.

24L, d_model=1024, 16H (head_dim 64), d_ff=2816 SwiGLU, vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    attn_bias=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
