"""hymba-1.5b [arXiv:2411.13676] — parallel attention + mamba heads.

32L, d_model=1600, 25H (GQA kv=5, head_dim 64), d_ff=5504, vocab=32001,
ssm_state=16.  Hybrid-head blocks: attention and SSD heads read the same
input in parallel and their outputs are averaged (per the paper's
fusion); sliding-window attention with 3 global layers (first/mid/last).
25 heads do not divide the 16-way model axis — attention runs
head-replicated under TP (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    sliding_window=1024,
    global_interval=16,  # sparse global layers
    mlp_type="swiglu",
    tie_embeddings=True,
)
