"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B] — 64 experts, top-6.

48L, d_model=2048, 16H (kv=16, head_dim 128), expert d_ff=1408,
vocab=163840.  (Moonlight additionally uses a shared expert + dense first
layer; we implement the routed-expert core per the assignment line.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    experts_per_token=6,
    mlp_type="swiglu",
    tie_embeddings=False,
    train_microbatches=2,
)
