"""gemma3-12b [hf:google/gemma-3 family] — 5:1 local:global attention, 128k.

48L, d_model=3840, 16H (GQA kv=8, head_dim 256), d_ff=15360 GeGLU,
vocab=262144.  Sliding window 1024 on local layers; every 6th layer global.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    mlp_type="geglu",
    sliding_window=1024,
    global_interval=6,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
