"""hubert-xlarge [arXiv:2106.07447] — encoder-only audio transformer.

48L, d_model=1280, 16H (kv=16, head_dim 80), d_ff=5120 GELU, vocab=504
(cluster targets).  The CNN waveform frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings.  Encoder-only → no decode shapes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    use_rope=True,  # stand-in for conv positional embedding
    mlp_type="gelu",
    frontend="audio",
    tie_embeddings=False,
)
