"""Model/config system for the assigned architectures.

One ``ModelConfig`` describes any member of the zoo: dense GQA transformers,
MoE, SSM (mamba2/SSD), hybrid (parallel attn+SSM heads), and the VLM/audio
backbones (modality frontends are stubs per the spec — ``input_specs()``
provides precomputed patch/frame embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention flavor
    attn_bias: bool = False  # qwen-style QKV bias
    causal: bool = True  # False → encoder-only (hubert)
    sliding_window: Optional[int] = None
    #: every k-th layer uses global attention (gemma3's 5:1 local:global)
    global_interval: Optional[int] = None
    rope_theta: float = 10000.0
    use_rope: bool = True
    logit_softcap: Optional[float] = None

    # norm / mlp flavor
    norm_type: str = "rmsnorm"  # rmsnorm | nonparam_ln
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # modality frontend stub ("vision" | "audio" | None)
    frontend: Optional[str] = None
    #: frontend tokens prepended to the text sequence (vlm)
    frontend_tokens: int = 0

    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # execution knobs (not architecture): loss chunking + attention algorithm
    ce_chunk: int = 512
    #: switch to blockwise (flash-style) attention above this S_q·S_kv
    blockwise_threshold: int = 2048
    #: unroll factor for the layer scan (analysis builds unroll fully so HLO
    #: op counts carry true trip counts)
    scan_unroll: int = 1
    #: gradient-accumulation microbatches for train_4k (memory lever for the
    #: biggest models; reduce-scatter of microbatch k overlaps compute of k+1)
    train_microbatches: int = 1
    #: shard d_model dims of weights over the data axis (FSDP).  Off → pure
    #: TP+DP: no per-layer weight gathers, optimizer state ×data-axis larger.
    shard_fsdp: bool = True
    #: sequence-shard the residual stream between layers (Megatron-SP).
    #: SSM blocks need the full sequence per layer, so for them this trades
    #: an AG+RS round trip per layer against saved-carry memory.
    seq_shard_acts: bool = True

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding/head rows padded to a multiple of 16 so the vocab dim
        shards over the model axis (92553→92560 etc.); padded logit columns
        are masked to -inf in the loss/heads."""
        return ((self.vocab_size + 15) // 16) * 16

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder(self) -> bool:
        """Encoder-only models have no decode step (skip decode shapes)."""
        return self.causal

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / mostly-sliding-window)."""
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window is not None
        )

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return max(1, self.d_inner // self.ssm_head_dim)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            qkv = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
            qkv += self.n_heads * self.head_dim * d  # wo
            if self.attn_bias:
                qkv += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
            per_layer += qkv
        if self.family == "moe":
            gates = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            per_layer += self.n_experts * (d * f * gates + f * d) + d * self.n_experts
        elif self.family in ("dense", "vlm", "audio", "hybrid"):
            gates = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            per_layer += d * f * gates + f * d
        if self.family in ("ssm", "hybrid"):
            di, N, Hs = self.d_inner, self.ssm_state, self.n_ssm_heads
            G = 1
            conv_dim = di + 2 * G * N
            per_layer += d * (2 * di + 2 * G * N + Hs)  # in_proj (z,x,B,C,dt)
            per_layer += conv_dim * self.conv_kernel
            per_layer += di * d  # out_proj
            per_layer += 3 * Hs  # A, D, dt_bias
        if self.norm_type != "nonparam_ln":
            per_layer += 2 * d
        total = emb + L * per_layer + (0 if self.norm_type == "nonparam_ln" else d)
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        gates = 2 if self.mlp_type in ("swiglu", "geglu") else 1
        per_expert = d * f * gates + f * d
        inactive = (self.n_experts - self.experts_per_token) * per_expert
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_is_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules from the assignment (documented in DESIGN.md)."""
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; 500k context out of envelope"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test configuration of the same family: tiny widths/depths."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=max(2, min(4, cfg.n_heads)),
        n_kv_heads=max(1, min(2, cfg.n_kv_heads)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        # no token dropping in smoke tests → decode path matches full forward
        moe_capacity_factor=max(cfg.moe_capacity_factor, 4.0),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.family in ("ssm", "hybrid") else 0,
        ssm_head_dim=32,
        ssm_chunk=16,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else None,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        dtype="float32",
    )
