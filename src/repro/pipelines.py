"""The paper's seven benchmark pipelines P1–P7 (§III.B) as ready-made graphs,
plus the catalog-driven multi-scene pipelines P8 (mosaic) and P9 (NDVI
time-series composite).

Each builder returns ``(pipeline, mapper)`` terminated by the given mapper
factory (defaults to an in-memory mapper; pass a ParallelRasterWriter factory
for file output, which reproduces the paper's parallel-write setup).

:func:`run_pipeline` executes any of them through the unified ExecutionPlan
layer: whichever executor is picked (streaming, thread pool, shard_map SPMD),
compiled plans come from one shared registry, so P1–P7 run on any engine —
and switching engines on matching geometry is a registry hit, not a
recompile.

``use_pallas`` on the kernel-backed builders (P2/P3/P5, ``chain_stages``) is
tri-state: ``True`` puts the plan on the fused Pallas fast path (interpret
mode off-TPU), ``False`` forces the jnp reference, and the default ``None``
defers to ``REPRO_USE_PALLAS`` / the backend
(:func:`repro.kernels.ops.resolve_use_pallas`).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from repro.core import Mapper, Pipeline, Source, Stage, StripeSplitter
from repro.filters import (
    Composite,
    Convert,
    HaralickTextures,
    MeanShift,
    Orthorectify,
    PansharpenFuse,
    RandomForestClassify,
    Resample,
    SensorModel,
    ndvi,
    train_forest,
)
from repro.raster import MemoryMapper


def _mapper(factory: Optional[Callable[[], Mapper]]) -> Mapper:
    return factory() if factory is not None else MemoryMapper()


def p1_orthorectification(
    src: Source, model: Optional[SensorModel] = None,
    out_rows: Optional[int] = None, out_cols: Optional[int] = None,
    mapper_factory=None, use_pallas: Optional[bool] = None,
) -> Tuple[Pipeline, Mapper]:
    p = Pipeline()
    s = p.add(src)
    info = p.info(s)
    model = model or SensorModel(
        a_rr=1.0, a_rc=0.02, a_cr=-0.02, a_cc=1.0, b_r=3.0, b_c=-2.0,
        disp_amp=2.0, disp_wavelength=700.0,
    )
    f = p.add(Orthorectify(model, out_rows or info.rows, out_cols or info.cols), [s])
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p2_textures(src: Source, mapper_factory=None, use_pallas: Optional[bool] = None,
                radius: int = 2, levels: int = 8) -> Tuple[Pipeline, Mapper]:
    p = Pipeline()
    s = p.add(src)
    f = p.add(HaralickTextures(radius=radius, levels=levels, use_pallas=use_pallas), [s])
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p3_pansharpening(xs: Source, pan: Source, ratio: int = 4,
                     mapper_factory=None, use_pallas: Optional[bool] = None) -> Tuple[Pipeline, Mapper]:
    p = Pipeline()
    sxs = p.add(xs)
    span = p.add(pan)
    up = p.add(Resample(ratio, method="bicubic", name="xs_up"), [sxs])
    fuse = p.add(PansharpenFuse(radius=ratio // 2, use_pallas=use_pallas), [up, span])
    m = p.add(_mapper(mapper_factory), [fuse])
    return p, m


def p4_classification(src: Source, n_classes: int = 4, n_train: int = 2000,
                      mapper_factory=None, seed: int = 0) -> Tuple[Pipeline, Mapper]:
    """Trains a small forest on synthetic labels derived from band rules, then
    classifies the image — self-contained like the paper's pre-trained model."""
    p = Pipeline()
    s = p.add(src)
    info = p.info(s)
    # draw training pixels from the source (host-side) + rule-based labels
    rng = np.random.default_rng(seed)
    from repro.core.region import ImageRegion

    rows = rng.integers(0, max(1, info.rows - 64), size=8)
    samples = []
    for r in rows:
        block = np.asarray(src.generate(ImageRegion((int(r), 0), (min(64, info.rows), min(256, info.cols)))))
        samples.append(block.reshape(-1, info.bands))
    X = np.concatenate(samples)[:n_train].astype(np.float32)
    # labels: quantile buckets of a band-mix index (deterministic ground truth)
    mix = X @ np.linspace(1.0, 2.0, info.bands)
    edges = np.quantile(mix, np.linspace(0, 1, n_classes + 1)[1:-1])
    y = np.digitize(mix, edges).astype(np.int64)
    forest = train_forest(X, y, n_trees=8, max_depth=8, seed=seed)
    f = p.add(RandomForestClassify(forest, mean=X.mean(0), std=X.std(0) + 1e-6), [s])
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p5_meanshift(src: Source, mapper_factory=None, use_pallas: Optional[bool] = None,
                 hs: int = 3, hr: float = 120.0, n_iter: int = 4) -> Tuple[Pipeline, Mapper]:
    p = Pipeline()
    s = p.add(src)
    f = p.add(MeanShift(hs=hs, hr=hr, n_iter=n_iter, use_pallas=use_pallas), [s])
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p6_conversion(src: Source, mapper_factory=None) -> Tuple[Pipeline, Mapper]:
    p = Pipeline()
    s = p.add(src)
    f = p.add(Convert(np.uint8, in_range=(0.0, 4096.0)), [s])
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p7_resampling(src: Source, factor: int = 4, mapper_factory=None) -> Tuple[Pipeline, Mapper]:
    p = Pipeline()
    s = p.add(src)
    f = p.add(Resample(factor, method="bicubic"), [s])
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p8_mosaic(
    catalog=None,
    rows: int = 48,
    cols: int = 32,
    n_scenes: int = 4,
    seed: int = 0,
    mapper_factory=None,
    use_pallas: Optional[bool] = None,
) -> Tuple[Pipeline, Mapper]:
    """P8: catalog-driven mosaic — a :class:`~repro.raster.SceneCatalog`
    assembled by :class:`~repro.raster.MosaicSource` (later scenes win
    overlaps), rescaled to reflectance.  ``catalog`` may be a SceneCatalog,
    a ready MosaicSource, or a list of SceneEntry; the default is the
    overlapping-quadrant demo catalog."""
    from repro.raster.catalog import MosaicSource, SceneCatalog, demo_catalog

    if catalog is None:
        catalog = demo_catalog(rows, cols, n_scenes=n_scenes, seed=seed)
    if isinstance(catalog, MosaicSource):
        src = catalog
    else:
        if not isinstance(catalog, SceneCatalog):
            catalog = SceneCatalog(list(catalog))
        src = MosaicSource(catalog)
    p = Pipeline()
    s = p.add(src)
    f = p.add(
        Convert(np.float32, in_range=(0.0, 4096.0), out_range=(0.0, 1.0)), [s]
    )
    m = p.add(_mapper(mapper_factory), [f])
    return p, m


def p9_ndvi_composite(
    *scenes: Source,
    periods: int = 3,
    rows: int = 48,
    cols: int = 32,
    seed: int = 0,
    op: str = "max",
    red_band: int = 0,
    nir_band: int = 3,
    mapper_factory=None,
    use_pallas: Optional[bool] = None,
) -> Tuple[Pipeline, Mapper]:
    """P9: NDVI time-series composite — per-date NDVI, reduced elementwise
    across dates (max-NDVI composite by default).  Pass the scenes as
    sources, as one :class:`~repro.raster.SceneCatalog` (composited in
    acquisition order), or nothing for the synthetic ``periods``-date demo
    series."""
    from repro.raster.catalog import SceneCatalog, demo_time_series

    if len(scenes) == 1 and isinstance(scenes[0], SceneCatalog):
        scenes = tuple(e.source for e in scenes[0].by_time())
    if not scenes:
        cat = demo_time_series(rows, cols, periods=periods, seed=seed)
        scenes = tuple(e.source for e in cat.by_time())
    p = Pipeline()
    heads = [
        p.add(ndvi(red_band, nir_band), [p.add(s)]) for s in scenes
    ]
    comp = p.add(Composite(len(heads), op=op), heads)
    m = p.add(_mapper(mapper_factory), [comp])
    return p, m


def io_passthrough(src: Source, mapper_factory=None) -> Tuple[Pipeline, Mapper]:
    """The paper's pure I/O pipeline (source + parallel writer)."""
    p = Pipeline()
    s = p.add(src)
    m = p.add(_mapper(mapper_factory), [s])
    return p, m


def chain_stages(
    rows_xs: int = 48,
    cols_xs: int = 32,
    seed: int = 0,
    n_workers: int = 2,
    n_splits: Optional[int] = None,
    texture_radius: int = 2,
    levels: int = 8,
    n_classes: int = 4,
    use_pallas: Optional[bool] = None,
):
    """Stage list for the ROADMAP chain pansharpen → texture → classify.

    The chain is built for the region-granularity pipelined orchestrator (and
    runs identically under the barrier oracle):

      * every stage ``build`` is **geometry-only** — in pipelined mode a
        consumer builds as soon as the upstream RTIF *header* exists, before
        any upstream pixels do, so the classifier forest is trained here,
        once, on synthetic texture-feature vectors (never on upstream
        pixels, unlike :func:`p4_classification` which samples its source);
      * every stage terminates in a commit-capable
        :class:`~repro.raster.ParallelRasterWriter` and splits output into
        full-width strips — the row-granularity commit protocol's contract.

    Returns a list of :class:`~repro.core.Stage` suitable for
    ``Orchestrator(chain_stages(...), pipelined=True)``.
    """
    from repro.filters.texture import FEATURES
    from repro.raster import ParallelRasterWriter, RasterReader, make_spot6_pair

    # pre-trained model (the paper's classification pipeline also loads a
    # trained model rather than fitting in-line)
    rng = np.random.default_rng(seed + 11)
    X = rng.normal(0.0, 1.0, size=(1024, len(FEATURES))).astype(np.float32)
    mix = X @ np.linspace(1.0, 2.0, len(FEATURES))
    edges = np.quantile(mix, np.linspace(0, 1, n_classes + 1)[1:-1])
    y = np.digitize(mix, edges).astype(np.int64)
    forest = train_forest(X, y, n_trees=8, max_depth=6, seed=seed)
    mean, std = X.mean(0), X.std(0) + 1e-6

    splitter = StripeSplitter(n_splits=n_splits) if n_splits else None

    def build_pansharpen(_inputs, out):
        xs, pan = make_spot6_pair(rows_xs, cols_xs, seed=seed)
        return p3_pansharpening(
            xs, pan,
            mapper_factory=lambda: ParallelRasterWriter(out),
            use_pallas=use_pallas,
        )

    def build_texture(inputs, out):
        return p2_textures(
            RasterReader(inputs["pansharpen"]),
            mapper_factory=lambda: ParallelRasterWriter(out),
            use_pallas=use_pallas, radius=texture_radius, levels=levels,
        )

    def build_classify(inputs, out):
        p = Pipeline()
        s = p.add(RasterReader(inputs["texture"]))
        f = p.add(RandomForestClassify(forest, mean=mean, std=std), [s])
        m = p.add(ParallelRasterWriter(out), [f])
        return p, m

    return [
        Stage("pansharpen", build_pansharpen, n_workers=n_workers,
              splitter=splitter),
        Stage("texture", build_texture, inputs=("pansharpen",),
              n_workers=n_workers, splitter=splitter),
        Stage("classify", build_classify, inputs=("texture",),
              n_workers=n_workers, splitter=splitter),
    ]


def build_tile_server(
    rows_xs: int = 32,
    cols_xs: int = 32,
    seed: int = 0,
    zooms: Tuple[int, ...] = (0, 1),
    pipelines: Tuple[str, ...] = ("P2", "P3", "P5"),
    tile_rows: int = 16,
    tile_cols: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    server=None,
    meanshift_iters: int = 2,
    **server_kw,
):
    """Register the kernel-backed pipelines (P2 textures, P3 pansharpening,
    P5 mean-shift) for tile serving across zoom levels.

    Zoom ``z`` serves the ``2**z`` overview view of each product, routed
    through the Source/Sink protocol (:func:`repro.serve.tiles.zoom_view`):
    pyramidal sources serve stored levels, everything else decimates on the
    fly (:class:`~repro.raster.DecimatedSource` — tile-window reads on the
    base, never the full image); P3 keeps its 4× PAN/XS ratio at every zoom
    by decimating both products.  Keep ``tile_rows``/``tile_cols`` multiples of
    the resample ratio (4) so P3 tiles share tap phase — interior tiles then
    collapse to one plan signature per zoom and batch together.

    Returns the (unstarted) :class:`~repro.serve.TileServer`; callers run
    ``server.warm()`` then either the synchronous ``serve()`` or
    ``start()``/``submit()``.  Extra keyword arguments construct the server
    (admission controller, batch sizes, tile cache size, ...).
    """
    from repro.raster.sources import SyntheticScene, make_spot6_pair
    from repro.serve import TileServer
    from repro.serve.tiles import zoom_view

    if server is None:
        server = TileServer(**server_kw)
    elif server_kw:
        raise ValueError("pass server_kw only when the server is built here")
    for z in zooms:

        def _zoomed(src: Source, _z=z) -> Source:
            # protocol overview(): stored pyramid levels for pyramidal
            # sources, DecimatedSource wrap for everything else
            return zoom_view(src, _z)

        if "P2" in pipelines:
            scene = SyntheticScene(rows_xs, cols_xs, bands=4, seed=seed, name=f"XS_z{z}")
            p, m = p2_textures(_zoomed(scene), use_pallas=use_pallas)
            server.register("P2", z, p, m, tile_rows, tile_cols)
        if "P3" in pipelines:
            xs, pan = make_spot6_pair(rows_xs, cols_xs, seed=seed)
            p, m = p3_pansharpening(_zoomed(xs), _zoomed(pan), use_pallas=use_pallas)
            server.register("P3", z, p, m, tile_rows, tile_cols)
        if "P5" in pipelines:
            scene = SyntheticScene(rows_xs, cols_xs, bands=4, seed=seed + 3, name=f"MS_z{z}")
            p, m = p5_meanshift(_zoomed(scene), use_pallas=use_pallas, n_iter=meanshift_iters)
            server.register("P5", z, p, m, tile_rows, tile_cols)
    return server


ALL = {
    "P1": p1_orthorectification,
    "P2": p2_textures,
    "P3": p3_pansharpening,
    "P4": p4_classification,
    "P5": p5_meanshift,
    "P6": p6_conversion,
    "P7": p7_resampling,
    "P8": p8_mosaic,
    "P9": p9_ndvi_composite,
    "IO": io_passthrough,
}


def run_pipeline(
    name,
    *sources,
    executor: str = "streaming",
    plan_cache=None,
    splitter=None,
    n_workers: Optional[int] = None,
    keep_outputs: bool = False,
    mapper_factory=None,
    sink=None,
    grid=None,
    **builder_kw,
):
    """Execute a benchmark pipeline through the shared ExecutionPlan registry.

    ``name`` is a key of :data:`ALL`, a builder callable, or an
    already-built ``(pipeline, mapper)`` pair.  ``executor`` is
    ``"streaming"`` (single-threaded double-buffered engine), ``"pool"``
    (``n_workers`` work-stealing threads, default 1) or ``"spmd"``
    (shard_map over the devices, capped at ``n_workers`` when given,
    otherwise all).  Under ``"spmd"``, ``grid=(nr, nc)`` lays the devices
    out as a 2-D tile grid (``nr × nc`` devices are used); the default is
    the 1-D ``(n, 1)`` strip decomposition.

    Sources and sinks go in as **protocol objects**, uniformly across every
    executor: each positional source may be a :class:`~repro.core.Source`, a
    file path (container magic picks RTIF vs tiled RTIC) or an ndarray
    (:func:`repro.raster.as_source`); ``sink=`` accepts a
    :class:`~repro.core.Mapper` or a path (``.rtic`` writes the tiled
    container, anything else the flat strip-parallel RTIF —
    :func:`repro.raster.as_sink`) and replaces ``mapper_factory``.

    Plan signatures are keyed by node identity, so registry reuse happens
    for runs of the *same built pipeline*: pass the ``(pipeline, mapper)``
    pair to run one graph on several executors — matching strip geometry is
    then a registry hit (zero re-lowers/re-compiles) instead of a retrace.
    A ``name``/builder argument constructs a fresh graph whose regions share
    plans within that run only.  ``plan_cache`` defaults to the process-wide
    registry (:func:`repro.core.global_plan_cache`, LRU-bounded); pass your
    own :class:`~repro.core.PlanCache` to isolate counters.

    Returns ``(StreamResult, mapper)``; the result's ``cache_stats`` exposes
    the registry counters whichever executor ran.
    """
    import os

    from repro.core import StreamingExecutor, global_plan_cache, run_pool
    from repro.core.parallel import ParallelExecutor
    from repro.raster.protocol import as_sink, as_source

    # paths/arrays coerce to protocol sources; Sources (and builder-specific
    # arguments like SceneCatalogs) pass through untouched
    sources = tuple(
        as_source(s) if isinstance(s, (str, os.PathLike, np.ndarray)) else s
        for s in sources
    )
    if sink is not None:
        if mapper_factory is not None:
            raise ValueError("pass sink= or mapper_factory=, not both")
        if isinstance(name, tuple):
            raise ValueError(
                "a prebuilt (pipeline, mapper) pair already carries its sink"
            )
        mapper_factory = lambda: as_sink(sink)  # noqa: E731

    if isinstance(name, tuple):
        pipeline, mapper = name
    else:
        build = ALL[name] if isinstance(name, str) else name
        pipeline, mapper = build(
            *sources, mapper_factory=mapper_factory, **builder_kw
        )
    cache = plan_cache if plan_cache is not None else global_plan_cache()
    if executor == "streaming":
        res = StreamingExecutor(
            pipeline, mapper, splitter, plan_cache=cache
        ).run(keep_outputs=keep_outputs)
    elif executor == "pool":
        res = run_pool(
            pipeline, mapper, splitter,
            n_workers=n_workers or 1, plan_cache=cache,
            keep_outputs=keep_outputs,
        )
    elif executor == "spmd":
        import jax

        take = grid[0] * grid[1] if grid is not None else n_workers
        devices = jax.devices()[:take] if take else None
        res = ParallelExecutor(
            pipeline, mapper, devices=devices, plan_cache=cache, grid=grid
        ).run(keep_outputs=keep_outputs)
    else:
        raise ValueError(f"unknown executor {executor!r}")
    return res, mapper
