"""Serving steps + a minimal batched engine.

``build_prefill_step`` / ``build_decode_step`` are the dry-run targets for
the prefill_32k / decode_32k / long_500k shapes.  ``ServeEngine`` runs
greedy/temperature generation over a batch of requests (quickstart-scale;
the host-side loop mirrors the streaming driver's role on the raster side).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def build_prefill_step(cfg: ModelConfig, max_seq: Optional[int] = None) -> Callable:
    def prefill_step(params, tokens):
        return lm.prefill(params, cfg, tokens, max_seq=max_seq)

    return prefill_step


def build_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens):
        return lm.decode_step(params, cfg, cache, tokens)

    return decode_step


class ServeEngine:
    """Batched greedy decoding with a fixed-size KV cache."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(build_prefill_step(cfg, max_seq))
        self._decode = jax.jit(build_decode_step(cfg))

    def generate(
        self, prompts: jnp.ndarray, max_new_tokens: int = 32,
        temperature: float = 0.0, key=None,
    ) -> jnp.ndarray:
        """prompts: (B, S0) int32 → (B, S0 + max_new_tokens)."""
        logits, cache = self._prefill(self.params, prompts)
        out = [prompts]
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            step_logits = logits[:, -1]
            if temperature > 0.0 and key is not None:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, step_logits / temperature)[:, None]
            else:
                tok = jnp.argmax(step_logits, axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
        return jnp.concatenate(out, axis=1)
