"""Admission control for the tile-serving engine.

Interactive tile traffic is bursty (a map pan fans one viewport move into
dozens of tile requests); the paper's batch drivers simply queue unbounded
work, which a serving front end cannot — queueing delay IS the latency.  The
controller bounds the number of requests admitted-but-not-completed:

  * ``shed`` policy (default): a request arriving at ``max_depth`` in-flight
    is rejected immediately with :class:`Shed` — the client re-requests the
    tile on its next pan frame, which beats queueing behind a storm;
  * ``block`` policy: the caller waits (bounded by ``max_wait_s``) for depth
    to drop, then sheds — the backpressure mode for trusted bulk clients.

The controller is a pure gatekeeper: it never touches the request payload,
so it sits in front of any engine.  Counters come out of
:meth:`AdmissionController.snapshot` as a plain dict, mirroring
``PlanCache.stats_snapshot``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional


class Shed(RuntimeError):
    """Raised to the caller when admission control rejects a request."""


@dataclasses.dataclass
class AdmissionStats:
    admitted: int = 0
    shed: int = 0
    completed: int = 0
    depth: int = 0
    high_water: int = 0

    def snapshot(self) -> Dict[str, int]:
        return {
            "admitted": self.admitted,
            "shed": self.shed,
            "completed": self.completed,
            "depth": self.depth,
            "high_water": self.high_water,
        }


class AdmissionController:
    """Bounded-depth admission gate (thread-safe).

    ``admit()`` raises :class:`Shed` when the bound cannot be honored;
    ``try_admit()`` is the bool-returning variant.  Every successful admit
    must be paired with exactly one ``release()`` (use :meth:`held` for a
    context-managed pairing).
    """

    def __init__(
        self,
        max_depth: int = 64,
        policy: str = "shed",
        max_wait_s: float = 0.5,
    ):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        if policy not in ("shed", "block"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.max_depth = int(max_depth)
        self.policy = policy
        self.max_wait_s = float(max_wait_s)
        self.stats = AdmissionStats()
        self._cond = threading.Condition()

    def try_admit(self, timeout: Optional[float] = None) -> bool:
        """Admit one request, or return False when the engine is saturated.
        Under ``block`` the call waits up to ``timeout`` (default
        ``max_wait_s``) for depth to drop before giving up."""
        deadline = None
        with self._cond:
            while self.stats.depth >= self.max_depth:
                if self.policy == "shed":
                    self.stats.shed += 1
                    return False
                wait = self.max_wait_s if timeout is None else timeout
                if deadline is None:
                    deadline = time.monotonic() + wait
                    remaining = wait
                else:
                    remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cond.wait(timeout=remaining):
                    self.stats.shed += 1
                    return False
            self.stats.depth += 1
            self.stats.admitted += 1
            self.stats.high_water = max(self.stats.high_water, self.stats.depth)
            return True

    def admit(self, timeout: Optional[float] = None) -> None:
        if not self.try_admit(timeout=timeout):
            raise Shed(
                f"admission shed: {self.stats.depth}/{self.max_depth} in "
                f"flight (policy={self.policy})"
            )

    def release(self) -> None:
        with self._cond:
            if self.stats.depth <= 0:
                raise RuntimeError("release() without a matching admit()")
            self.stats.depth -= 1
            self.stats.completed += 1
            self._cond.notify()

    class _Held:
        def __init__(self, ctl: "AdmissionController"):
            self._ctl = ctl

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self._ctl.release()
            return False

    def held(self, timeout: Optional[float] = None) -> "_Held":
        """``with controller.held(): ...`` — admit (raising :class:`Shed` on
        saturation) and release on exit, error paths included."""
        self.admit(timeout=timeout)
        return AdmissionController._Held(self)

    def snapshot(self) -> Dict[str, int]:
        with self._cond:
            return self.stats.snapshot()
