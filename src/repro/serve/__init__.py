from repro.serve.engine import build_prefill_step, build_decode_step, ServeEngine

__all__ = ["build_prefill_step", "build_decode_step", "ServeEngine"]
