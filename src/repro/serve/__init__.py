from repro.serve.engine import build_prefill_step, build_decode_step, ServeEngine
from repro.serve.admission import AdmissionController, AdmissionStats, Shed
from repro.serve.tiles import TileGrid, TileRequest, TileServer, zoom_view

__all__ = [
    "build_prefill_step",
    "build_decode_step",
    "ServeEngine",
    "AdmissionController",
    "AdmissionStats",
    "Shed",
    "TileGrid",
    "TileRequest",
    "TileServer",
    "zoom_view",
]
