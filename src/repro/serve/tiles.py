"""Plan-warm tile serving: a long-running, signature-batched request engine.

The batch executors answer "run this pipeline over this image"; interactive
traffic asks something else — millions of map clients each pulling one
``(pipeline, zoom, x, y)`` tile with a latency budget.  This engine treats
every tile request as a region pull through the ExecutionPlan layer and
spends the registry built in PRs 2–7 on latency:

  * **The plan signature is the batch key.**  Requests queued together whose
    tile regions describe to the same canonical signature coalesce into ONE
    invocation of a ``jax.vmap``-batched build of the shared compiled plan
    (:class:`~repro.core.streaming.BatchedRegionPuller`): N tiles, one XLA
    dispatch, bit-identical to N per-tile pulls.
  * **Admission control** (:mod:`repro.serve.admission`) bounds the number of
    admitted-but-uncompleted requests; past the bound the policy sheds (or
    blocks, for bulk clients) instead of letting queueing delay eat p99.
  * **Warm-up protocol**: :meth:`TileServer.warm` sweeps every registered
    tile geometry through describe → lower → compile (single and batched
    buckets), so the first live request is a pure registry hit — zero new
    lowers, zero new compiles (``bench_serving`` gates this).
  * **Per-zoom neighbor prefetch**: serving tile ``(x, y)`` enqueues its grid
    neighbors to a per-zoom background :class:`~repro.data.pipeline.Prefetcher`
    feeding a small host-side tile cache — the panning client's next request
    is often already materialized.

The dispatcher is deliberately a single thread: batching happens naturally
(whatever accumulated in the request queue during the previous batch forms
the next one — load, not a timer, sets the batch size), and the compiled
programs it dispatches already own the parallelism.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import queue
import threading
from concurrent.futures import Future
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.execplan import PlanCache, global_plan_cache
from repro.core.pipeline import Pipeline
from repro.core.region import ImageRegion
from repro.core.streaming import BatchedRegionPuller
from repro.data.pipeline import Prefetcher
from repro.serve.admission import AdmissionController, Shed


def zoom_view(source, zoom: int):
    """The source to read when serving zoom level ``zoom``.

    Routed through the Source/Sink protocol's ``overview(level)``: pyramidal
    sources (tiled RTIC containers) serve their *stored* overview levels —
    a zoom tile then costs a few range reads of pre-decimated data — and
    everything else falls back to an on-the-fly
    :class:`~repro.raster.sources.DecimatedSource` wrap (``2**zoom``
    decimation, tile-window reads on the base).  Both views sample the same
    grid (level pixel ``(r, c)`` = base pixel ``(r*2**z, c*2**z)``), so the
    served bytes are identical either way.
    """
    if zoom <= 0:
        return source
    overview = getattr(source, "overview", None)
    if callable(overview):
        return overview(int(zoom))
    from repro.raster.sources import DecimatedSource

    return DecimatedSource(source, 2 ** int(zoom))


@dataclasses.dataclass(frozen=True)
class TileRequest:
    """One map-tile request: which pipeline, which zoom level, which tile."""

    pipeline: str
    zoom: int
    x: int
    y: int


class TileGrid:
    """The tile grid over one zoom level's output image.

    Tile ``(x, y)`` covers rows ``[y*tile_rows, ...)`` and columns
    ``[x*tile_cols, ...)``; edge tiles clamp to the image (ragged tiles keep
    their true size — exactly the geometry the describe pass signatures)."""

    def __init__(self, rows: int, cols: int, tile_rows: int, tile_cols: int):
        if rows < 1 or cols < 1 or tile_rows < 1 or tile_cols < 1:
            raise ValueError(
                f"bad grid geometry: image {rows}x{cols}, "
                f"tile {tile_rows}x{tile_cols}"
            )
        self.rows, self.cols = rows, cols
        self.tile_rows, self.tile_cols = tile_rows, tile_cols
        self.ny = -(-rows // tile_rows)
        self.nx = -(-cols // tile_cols)

    def __contains__(self, xy: Tuple[int, int]) -> bool:
        x, y = xy
        return 0 <= x < self.nx and 0 <= y < self.ny

    def region(self, x: int, y: int) -> ImageRegion:
        if (x, y) not in self:
            raise KeyError(
                f"tile ({x}, {y}) outside grid {self.nx}x{self.ny}"
            )
        r0, c0 = y * self.tile_rows, x * self.tile_cols
        return ImageRegion(
            (r0, c0),
            (min(self.tile_rows, self.rows - r0),
             min(self.tile_cols, self.cols - c0)),
        )

    def tiles(self) -> Iterator[Tuple[int, int]]:
        return itertools.product(range(self.nx), range(self.ny))

    def neighbors(self, x: int, y: int) -> List[Tuple[int, int]]:
        """The up-to-8 grid neighbors of tile ``(x, y)`` — the tiles a
        panning client is most likely to request next."""
        out = []
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                if (dx, dy) != (0, 0) and (x + dx, y + dy) in self:
                    out.append((x + dx, y + dy))
        return out


@dataclasses.dataclass
class _Entry:
    """One registered (pipeline name, zoom) serving target."""

    name: str
    zoom: int
    pipeline: Pipeline
    node: object
    grid: TileGrid
    puller: BatchedRegionPuller
    # neighbor prefetch plumbing (created at start(), torn down at stop())
    pending: Optional["queue.Queue"] = None
    prefetcher: Optional[Prefetcher] = None


class _TileCache:
    """Small thread-safe LRU of materialized tiles (host arrays)."""

    def __init__(self, max_entries: int):
        self.max_entries = max_entries
        self._d: "collections.OrderedDict[TileRequest, np.ndarray]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: TileRequest) -> Optional[np.ndarray]:
        if self.max_entries <= 0:
            return None
        with self._lock:
            tile = self._d.get(key)
            if tile is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return tile

    def put(self, key: TileRequest, tile: np.ndarray) -> None:
        if self.max_entries <= 0:
            return
        with self._lock:
            self._d[key] = tile
            self._d.move_to_end(key)
            while len(self._d) > self.max_entries:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


class TileServer:
    """The long-running serving front end.

    Synchronous API: :meth:`serve` / :meth:`serve_one` pull tiles on the
    caller's thread (requests within one :meth:`serve` call still batch by
    signature).  Request-engine API: :meth:`start` spins up the batching
    dispatcher, :meth:`submit` enqueues a request and returns a
    :class:`~concurrent.futures.Future` — concurrent clients' requests
    coalesce into signature batches sized by whatever the queue holds when
    the dispatcher comes around (bounded by ``max_batch``).

    ``tile_cache_entries=0`` disables the host tile cache (and with it
    neighbor prefetch) — the benchmark uses that to measure the compiled
    path itself rather than dict lookups.
    """

    _STOP = object()

    def __init__(
        self,
        plan_cache: Optional[PlanCache] = None,
        admission: Optional[AdmissionController] = None,
        max_batch: int = 16,
        batch_sizes: Tuple[int, ...] = (1, 4, 16),
        tile_cache_entries: int = 256,
        read_cache_entries: int = 1024,
        prefetch_neighbors: bool = True,
        prefetch_depth: int = 8,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.plan_cache = (
            plan_cache if plan_cache is not None else global_plan_cache()
        )
        self.admission = admission or AdmissionController()
        self.max_batch = int(max_batch)
        # batched programs never trace above max_batch — drop larger buckets
        self.batch_sizes = tuple(
            b for b in sorted(set(batch_sizes)) if b <= self.max_batch
        ) or (self.max_batch,)
        self.tile_cache = _TileCache(tile_cache_entries)
        self.read_cache_entries = int(read_cache_entries)
        self.prefetch_neighbors = (
            bool(prefetch_neighbors) and tile_cache_entries > 0
        )
        self.prefetch_depth = int(prefetch_depth)
        self._entries: Dict[Tuple[str, int], _Entry] = {}
        self._rq: "queue.Queue" = queue.Queue()
        self._dispatcher: Optional[threading.Thread] = None
        self._dispatch_error: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._seen_prefetch: set = set()
        # serving metrics (dispatcher-thread writes, snapshot reads)
        self._batch_hist: Dict[int, int] = collections.defaultdict(int)
        self._requests = 0
        self._prefetch_enqueued = 0
        self._prefetch_stored = 0

    # -- registration / warm-up ---------------------------------------------
    def register(
        self,
        name: str,
        zoom: int,
        pipeline: Pipeline,
        node,
        tile_rows: int = 32,
        tile_cols: Optional[int] = None,
    ) -> _Entry:
        """Register one (pipeline, zoom) serving target.  ``node`` is the
        graph node whose pixels the tiles carry (typically the mapper — an
        identity in the data graph).  Pipelines with persistent filters are
        refused by the puller: tile responses must not depend on request
        order."""
        key = (name, int(zoom))
        if key in self._entries:
            raise ValueError(f"{key} already registered")
        info = pipeline.info(node)
        grid = TileGrid(
            info.rows, info.cols, tile_rows, tile_cols or tile_rows
        )
        puller = BatchedRegionPuller(
            pipeline, node, plan_cache=self.plan_cache,
            batch_sizes=self.batch_sizes,
            read_cache_entries=self.read_cache_entries,
        )
        entry = _Entry(name, int(zoom), pipeline, node, grid, puller)
        self._entries[key] = entry
        return entry

    def entries(self) -> List[Tuple[str, int]]:
        return sorted(self._entries)

    def warm(
        self, pipelines=None, zooms=None, buckets=None
    ) -> Dict[str, Dict[str, int]]:
        """Warm every registered (or selected) serving target: lower +
        compile each distinct tile signature and prime the batched programs,
        so the first live request afterwards performs zero lowers and zero
        compiles.  Returns per-target warm stats (signature counts + plan
        cache deltas)."""
        out: Dict[str, Dict[str, int]] = {}
        for (name, zoom), entry in sorted(self._entries.items()):
            if pipelines is not None and name not in pipelines:
                continue
            if zooms is not None and zoom not in zooms:
                continue
            regions = [entry.grid.region(x, y) for x, y in entry.grid.tiles()]
            out[f"{name}/z{zoom}"] = entry.puller.warm(regions, buckets=buckets)
        return out

    # -- request plumbing ----------------------------------------------------
    def _resolve(self, req: TileRequest) -> Tuple[_Entry, ImageRegion]:
        entry = self._entries.get((req.pipeline, req.zoom))
        if entry is None:
            raise KeyError(
                f"no serving entry for pipeline {req.pipeline!r} zoom "
                f"{req.zoom} (registered: {self.entries()})"
            )
        return entry, entry.grid.region(req.x, req.y)

    def _finish_tiles(self, served: List[Tuple[TileRequest, np.ndarray]]):
        for req, tile in served:
            self.tile_cache.put(req, tile)
            if self.prefetch_neighbors:
                self._enqueue_neighbors(req)

    def serve(self, requests: List[TileRequest]) -> List[np.ndarray]:
        """Synchronous bulk serve: one batched invocation per signature
        group, admission held per ``max_batch``-sized chunk (a bulk caller
        never monopolizes the admission budget for its whole list).  Order
        of outputs matches inputs."""
        out: List[Optional[np.ndarray]] = [None] * len(requests)
        by_entry: Dict[Tuple[str, int], List[int]] = {}
        self._requests += len(requests)
        for i, req in enumerate(requests):
            self._resolve(req)  # raises on unknown entry / bad tile coords
            cached = self.tile_cache.get(req)
            if cached is not None:
                out[i] = cached
                continue
            by_entry.setdefault((req.pipeline, req.zoom), []).append(i)
        chunk = max(1, min(self.max_batch, self.admission.max_depth))
        for key, idxs in by_entry.items():
            entry = self._entries[key]
            for start in range(0, len(idxs), chunk):
                part = idxs[start:start + chunk]
                admitted = 0
                try:
                    for _ in part:
                        self.admission.admit()
                        admitted += 1
                    regions = [
                        entry.grid.region(requests[i].x, requests[i].y)
                        for i in part
                    ]
                    tiles = entry.puller.pull_many(regions)
                    self._batch_hist[len(part)] += 1
                    for i, tile in zip(part, tiles):
                        out[i] = tile
                    self._finish_tiles(
                        [(requests[i], t) for i, t in zip(part, tiles)]
                    )
                finally:
                    for _ in range(admitted):
                        self.admission.release()
        return out  # type: ignore[return-value]

    def serve_one(self, req: TileRequest) -> np.ndarray:
        return self.serve([req])[0]

    # -- the request engine: dispatcher thread + futures ---------------------
    def start(self) -> "TileServer":
        if self._dispatcher is not None:
            raise RuntimeError("server already started")
        self._dispatch_error = None
        if self.prefetch_neighbors:
            for entry in self._entries.values():
                self._start_prefetch(entry)
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="tile-dispatch", daemon=True
        )
        self._dispatcher.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        """Idempotent shutdown: stops the dispatcher (pending futures still
        complete — the sentinel queues behind them) and tears down every
        per-zoom prefetcher."""
        if self._dispatcher is not None:
            self._rq.put(self._STOP)
            self._dispatcher.join(timeout=timeout)
            self._dispatcher = None
        for entry in self._entries.values():
            self._stop_prefetch(entry)

    def __enter__(self) -> "TileServer":
        return self.start() if self._dispatcher is None else self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    def submit(self, req: TileRequest) -> "Future[np.ndarray]":
        """Enqueue one tile request; the future resolves with the tile (or
        raises :class:`~repro.serve.admission.Shed` when admission rejects,
        or whatever error the pipeline raised)."""
        if self._dispatcher is None:
            raise RuntimeError("server not started — use serve()/serve_one()")
        if self._dispatch_error is not None:
            raise RuntimeError("dispatcher died") from self._dispatch_error
        fut: "Future[np.ndarray]" = Future()
        cached = self.tile_cache.get(req)
        if cached is not None:
            fut.set_result(cached)
            return fut
        if not self.admission.try_admit():
            fut.set_exception(
                Shed(f"admission shed at depth {self.admission.max_depth}")
            )
            return fut
        self._rq.put((req, fut))
        return fut

    def _dispatch_loop(self) -> None:
        try:
            stopping = False
            while not stopping:
                item = self._rq.get()
                if item is self._STOP:
                    return
                batch = [item]
                while len(batch) < self.max_batch:
                    try:
                        nxt = self._rq.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is self._STOP:
                        stopping = True
                        break
                    batch.append(nxt)
                self._process_batch(batch)
                self._drain_prefetched()
        except BaseException as e:  # noqa: BLE001 — surfaced via submit()
            self._dispatch_error = e
            raise

    def _process_batch(self, batch) -> None:
        self._requests += len(batch)
        by_entry: Dict[Tuple[str, int], List[Tuple[TileRequest, Future]]] = {}
        for req, fut in batch:
            try:
                self._resolve(req)
            except Exception as e:
                fut.set_exception(e)
                self.admission.release()
                continue
            by_entry.setdefault((req.pipeline, req.zoom), []).append((req, fut))
        for key, items in by_entry.items():
            entry = self._entries[key]
            regions = [entry.grid.region(r.x, r.y) for r, _ in items]
            try:
                tiles = entry.puller.pull_many(regions)
            except BaseException as e:  # noqa: BLE001 — fail the futures
                for _, fut in items:
                    fut.set_exception(e)
                    self.admission.release()
                continue
            self._batch_hist[len(items)] += 1
            for (req, fut), tile in zip(items, tiles):
                fut.set_result(tile)
                self.admission.release()
            self._finish_tiles([(req, t) for (req, _), t in zip(items, tiles)])

    # -- per-zoom neighbor prefetch ------------------------------------------
    def _start_prefetch(self, entry: _Entry) -> None:
        pending: "queue.Queue" = queue.Queue(maxsize=4 * self.prefetch_depth)

        def gen():
            while True:
                req = pending.get()
                if req is None:
                    return
                if self.tile_cache.get(req) is not None:
                    continue
                tile = entry.puller.pull_one(
                    entry.grid.region(req.x, req.y)
                )
                yield req, tile

        entry.pending = pending
        entry.prefetcher = Prefetcher(gen(), depth=self.prefetch_depth)

    def _stop_prefetch(self, entry: _Entry) -> None:
        if entry.prefetcher is None:
            return
        pending, prefetcher = entry.pending, entry.prefetcher
        entry.pending = entry.prefetcher = None
        try:  # drain queued coords so the sentinel lands promptly
            while True:
                pending.get_nowait()
        except queue.Empty:
            pass
        try:
            pending.put_nowait(None)
        except queue.Full:
            pass
        prefetcher.close()

    def _enqueue_neighbors(self, req: TileRequest) -> None:
        entry = self._entries.get((req.pipeline, req.zoom))
        if entry is None or entry.pending is None:
            return
        with self._lock:
            if len(self._seen_prefetch) > 4096:
                self._seen_prefetch.clear()
            for x, y in entry.grid.neighbors(req.x, req.y):
                nreq = TileRequest(req.pipeline, req.zoom, x, y)
                if nreq in self._seen_prefetch:
                    continue
                if self.tile_cache.get(nreq) is not None:
                    continue
                try:
                    entry.pending.put_nowait(nreq)
                except queue.Full:
                    return  # prefetch is best-effort: drop under pressure
                self._seen_prefetch.add(nreq)
                self._prefetch_enqueued += 1

    def _drain_prefetched(self) -> None:
        """Move completed neighbor prefetches into the tile cache (called
        opportunistically from the dispatcher; safe from any thread)."""
        for entry in self._entries.values():
            pf = entry.prefetcher
            if pf is None:
                continue
            while True:
                item = pf.poll()
                if item is None:
                    break
                req, tile = item
                self.tile_cache.put(req, tile)
                self._prefetch_stored += 1

    # -- observability -------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        """One plain-dict snapshot of every layer's counters: the plan
        registry (``PlanCache.stats_snapshot``), admission, batching
        histogram, tile cache and prefetch activity."""
        self._drain_prefetched()
        return {
            "plan_cache": self.plan_cache.stats_snapshot(),
            "admission": self.admission.snapshot(),
            "requests": self._requests,
            "batch_histogram": dict(sorted(self._batch_hist.items())),
            "tile_cache": {
                "entries": len(self.tile_cache),
                "hits": self.tile_cache.hits,
                "misses": self.tile_cache.misses,
            },
            "prefetch": {
                "enqueued": self._prefetch_enqueued,
                "stored": self._prefetch_stored,
            },
            "read_cache": {
                "hits": sum(
                    e.puller.read_hits for e in self._entries.values()
                ),
                "misses": sum(
                    e.puller.read_misses for e in self._entries.values()
                ),
            },
        }
