"""Sharded checkpointing with strip-parallel writes + atomic commit.

This is the paper's parallel raster writer (§II.D) applied to model state:
every parameter array is written as row-strips into one pre-sized file, so
N writers (per-host threads standing in for per-host processes) write
disjoint byte ranges of the same file concurrently — MPI-IO semantics.  A
fixed-size JSON manifest plus a COMMIT marker make the checkpoint atomic:
readers ignore directories without COMMIT, so a mid-save failure never
corrupts the restore path (crash-consistent).

Layout:
    <dir>/step_<k>/
        manifest.json       # leaf paths, shapes, dtypes, strip table, hashes
        <leaf>.bin          # raw row-major bytes, strip-writable
        COMMIT              # written last (atomic rename)
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401 — side-effect: registers bfloat16 with numpy
import numpy as np


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


def _strips(rows: int, n: int) -> List[Tuple[int, int]]:
    base, extra = divmod(rows, n)
    out, r = [], 0
    for i in range(n):
        h = base + (1 if i < extra else 0)
        if h:
            out.append((r, r + h))
        r += h
    return out


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    n_writers: int = 8,
    keep: int = 3,
) -> str:
    """Write ``state`` (any pytree of arrays) atomically; returns the path."""
    base = pathlib.Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _leaf_paths(state)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    jobs = []
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        fname = name.replace("/", "__") + ".bin"
        rows = arr.shape[0] if arr.ndim else 1
        flat2d = arr.reshape(rows, -1) if arr.ndim else arr.reshape(1, 1)
        strips = _strips(rows, min(n_writers, rows))
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": arr.dtype.name,
            "strips": strips,
            "sha256": hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest(),
        }
        path = tmp / fname
        with open(path, "wb") as f:  # pre-size: strip writers mmap into place
            f.truncate(flat2d.nbytes if flat2d.nbytes else 1)
        row_bytes = flat2d.dtype.itemsize * flat2d.shape[1]
        for (r0, r1) in strips:
            jobs.append((path, flat2d, r0, r1, row_bytes))

    def write_strip(job):
        path, flat2d, r0, r1, row_bytes = job
        mm = np.memmap(path, dtype=flat2d.dtype, mode="r+",
                       offset=r0 * row_bytes, shape=(r1 - r0, flat2d.shape[1]))
        mm[:] = flat2d[r0:r1]
        mm.flush()

    with ThreadPoolExecutor(max_workers=n_writers) as pool:
        list(pool.map(write_strip, jobs))

    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # retention
    ckpts = sorted(p for p in base.iterdir() if p.name.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    base = pathlib.Path(directory)
    if not base.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in base.iterdir()
        if p.name.startswith("step_") and (p / "COMMIT").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: Optional[int] = None,
    like: Any = None,
    shardings: Any = None,
    verify: bool = False,
) -> Tuple[int, Any]:
    """Load a checkpoint; optionally device_put with ``shardings`` (elastic
    restore onto any mesh — the saved format is mesh-independent)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays: Dict[str, np.ndarray] = {}
    for name, meta in manifest["leaves"].items():
        arr = np.fromfile(d / meta["file"], dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        if verify:
            got = hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()
            if got != meta["sha256"]:
                raise IOError(f"checksum mismatch for {name}")
        arrays[name] = arr

    if like is None:
        return step, arrays
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings, is_leaf=lambda x: x is None)[0]
        if shardings is not None
        else None
    )
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        arr = arrays[name].astype(leaf.dtype) if hasattr(leaf, "dtype") else arrays[name]
        if shard_flat is not None and shard_flat[i] is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in flight)."""

    def __init__(self, directory: str, n_writers: int = 8, keep: int = 3):
        self.directory = directory
        self.n_writers = n_writers
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def run():
            self.last_path = save_checkpoint(
                self.directory, step, host_state, self.n_writers, self.keep
            )

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
