from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ckpt.elastic import recover, shrink_mesh

__all__ = [
    "AsyncCheckpointer",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
    "recover",
    "shrink_mesh",
]
