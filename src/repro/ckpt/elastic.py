"""Elastic scaling + fault recovery (beyond the paper's dozen-node limit).

The checkpoint format is mesh-independent (full logical arrays, strip files),
so recovery from node loss is: rebuild a mesh from the surviving devices,
re-derive shardings for the new mesh, and ``device_put`` the restored state.
``shrink_mesh`` picks the largest (data × model) grid that fits the
survivors while preserving the model-axis size when possible (TP degree is
tied to weight divisibility; DP/FSDP degree is free).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.ckpt.checkpoint import restore_checkpoint
from repro.configs.base import ModelConfig
from repro.models.sharding import ShardingRules


def shrink_mesh(
    devices: Sequence,
    prefer_model: int,
    axis_names: Tuple[str, str] = ("data", "model"),
) -> Mesh:
    """Largest usable (data × model) mesh from surviving devices."""
    n = len(devices)
    model = prefer_model
    while model > 1 and (n % model or model > n):
        model //= 2
    data = n // model
    use = list(devices)[: data * model]
    return Mesh(np.array(use).reshape(data, model), axis_names)


def recover(
    ckpt_dir: str,
    cfg: ModelConfig,
    surviving_devices: Sequence,
    like_state,
    prefer_model: int = 1,
):
    """Restore the latest committed checkpoint onto a rebuilt mesh.
    Returns (step, state, mesh, rules)."""
    mesh = shrink_mesh(surviving_devices, prefer_model)
    rules = ShardingRules(mesh, cfg)
    pspecs = rules.param_specs(like_state)
    step, state = restore_checkpoint(
        ckpt_dir, like=like_state, shardings=pspecs
    )
    return step, state, mesh, rules
