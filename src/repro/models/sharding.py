"""Sharding rules: map every param / batch / cache leaf to a PartitionSpec.

Production mesh axes (launch/mesh.py):
  single-pod : ("data", "model")            — 16 × 16 = 256 chips
  multi-pod  : ("pod", "data", "model")     — 2 × 16 × 16 = 512 chips

Strategy (FSDP + TP hybrid, DP across pods):
  * params: d_model dims sharded over "data" (FSDP — gathered per layer
    inside the scan), head/ff/expert dims over "model" (TP);
  * activations: batch over ("pod", "data");
  * a dim is sharded over "model"/"data" only when divisible — otherwise
    replicated on that axis (e.g. hymba's 25 q-heads, gemma-2b's kv=1;
    recorded per-arch in DESIGN.md §Arch-applicability);
  * decode caches: batch-sharded when the batch divides the dp axes,
    sequence-sharded otherwise (long_500k with B=1 → context parallelism).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# --- activation batch-sharding constraint (set by the launcher) -------------
# The embedding gather drops batch sharding during propagation (measured: the
# whole residual stream and attention scores come out batch-replicated), so
# the model inserts an explicit constraint on the token/batch axis.  Module
# state avoids threading mesh objects through the pure model code; smoke
# tests leave it unset (no-op).
_BATCH_AXES: Optional[Tuple[str, ...]] = None
_TP_SIZE: int = 1


_MESH: Optional[Mesh] = None
_SEQ_SHARD: bool = True


def set_batch_axes(axes: Optional[Tuple[str, ...]], tp_size: int = 1,
                   dp: int = 1, mesh: Optional[Mesh] = None,
                   seq_shard: bool = True):
    global _BATCH_AXES, _TP_SIZE, _DP_SIZE, _MESH, _SEQ_SHARD
    _BATCH_AXES = tuple(axes) if axes else None
    _TP_SIZE = tp_size
    _DP_SIZE = dp
    _MESH = mesh
    _SEQ_SHARD = seq_shard


def current_mesh() -> Optional[Mesh]:
    return _MESH


def batch_axes() -> Optional[Tuple[str, ...]]:
    return _BATCH_AXES


def constrain_batch(x):
    """Constrain dim 0 of ``x`` to the data-parallel axes (if configured)."""
    if _BATCH_AXES is None or x.ndim < 2:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, P(_BATCH_AXES, *([None] * (x.ndim - 1)))
        )
    except Exception:  # outside a mesh context (unit tests)
        return x


def dp_size() -> int:
    """Configured data-parallel world size (1 when unset — unit tests)."""
    global _DP_SIZE
    return _DP_SIZE


_DP_SIZE: int = 1


def constrain_groups(x):
    """MoE token groups (G, Tg, d): groups over the dp axes."""
    if _BATCH_AXES is None or x.ndim < 2 or x.shape[0] % max(1, _DP_SIZE):
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, P(_BATCH_AXES, *([None] * (x.ndim - 1)))
        )
    except Exception:
        return x


def constrain_expert_buffers(x):
    """MoE dispatch buffers (G, E, C, ·): groups over the dp axes, experts
    over "model".  Without this the gathered token buffers replicate —
    measured 5 GiB/device on olmoe train_4k."""
    if _BATCH_AXES is None or x.ndim < 3:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, P(_BATCH_AXES, "model", *([None] * (x.ndim - 2)))
        )
    except Exception:
        return x


def constrain_act(x):
    """Activation constraint for (B, S, d) residual-stream tensors: batch over
    the dp axes AND sequence over "model" (Megatron-style sequence
    parallelism).  Without the S shard, the layer-scan backward saves
    L·B_local·S·d carries — measured 24 GiB/device on gemma3-12B train_4k
    (438% of HBM); with it, 1.5 GiB."""
    if _BATCH_AXES is None or x.ndim != 3:
        return constrain_batch(x)
    if _SEQ_SHARD and _TP_SIZE > 1 and x.shape[1] % _TP_SIZE == 0:
        spec = P(_BATCH_AXES, "model", None)
    else:
        spec = P(_BATCH_AXES, None, None)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


class ShardingRules:
    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.has_pod = "pod" in self.axes
        self.dp_axes: Tuple[str, ...] = (
            ("pod", "data") if self.has_pod else ("data",)
        )
        self.dp_size = int(np.prod([self.axes[a] for a in self.dp_axes]))
        self.tp = self.axes.get("model", 1)
        self.fsdp = self.axes.get("data", 1)

    # -- helpers -------------------------------------------------------------
    def _model(self, n: int) -> Optional[str]:
        return "model" if n % self.tp == 0 else None

    def _data(self, n: int) -> Optional[str]:
        if not getattr(self.cfg, "shard_fsdp", True):
            return None
        return "data" if n % self.fsdp == 0 else None

    def ns(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    # -- params ----------------------------------------------------------------
    def param_specs(self, params) -> Dict[str, Any]:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.vocab_size
        nh, nkv, f, E = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.n_experts
        H = cfg.n_ssm_heads if cfg.family in ("ssm", "hybrid") else 1
        di = cfg.d_inner

        md, dd = self._model, self._data
        rules = {
            "embed": P(md(V), dd(d)),
            "lm_head": P(dd(d), md(V)),
            "final_norm": P(None),
            # attention
            "wq": P(None, dd(d), md(nh)),
            "wk": P(None, dd(d), md(nkv)),
            "wv": P(None, dd(d), md(nkv)),
            "wo": P(None, md(nh), dd(d)),
            "bq": P(None, md(nh)),
            "bk": P(None, md(nkv)),
            "bv": P(None, md(nkv)),
            # dense mlp
            "wg": P(None, dd(d), md(f)),
            "wu": P(None, dd(d), md(f)),
            "wd_": P(None, md(f), dd(d)),
            # moe
            "router": P(None, dd(d), None),
            "mwg": P(None, md(E), dd(d), None),
            "mwu": P(None, md(E), dd(d), None),
            "mwd": P(None, md(E), None, dd(d)),
            # ssm
            "swz": P(None, dd(d), md(H)),
            "swx": P(None, dd(d), md(H)),
            "swB": P(None, dd(d), None),
            "swC": P(None, dd(d), None),
            "swdt": P(None, dd(d), md(H)),
            "sconv": P(None, None, None),
            "sA_log": P(None, None),
            "sD": P(None, None),
            "sdt_bias": P(None, None),
            "snorm": P(None, None),
            "sout": P(None, md(H), dd(d)),
            "norm1": P(None, None),
            "norm2": P(None, None),
        }

        def spec_for(path: str, leaf) -> NamedSharding:
            name = path.split("/")[-1]
            p = rules.get(name, P())
            # trim to leaf rank (biases etc.)
            p = P(*tuple(p)[: leaf.ndim]) if len(tuple(p)) > leaf.ndim else p
            return NamedSharding(self.mesh, p)

        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        specs = []
        for path, leaf in flat:
            spath = "/".join(
                getattr(k, "key", str(getattr(k, "idx", ""))) for k in path
            )
            specs.append(spec_for(spath, leaf))
        return jax.tree_util.tree_unflatten(treedef, specs)

    # -- batches -----------------------------------------------------------------
    def batch_specs(self, batch_tree) -> Any:
        dp = self.dp_axes

        def spec(leaf):
            if leaf.ndim >= 2 and leaf.shape[0] % self.dp_size == 0:
                return self.ns(dp, *([None] * (leaf.ndim - 1)))
            return self.ns()

        return jax.tree.map(spec, batch_tree)

    # -- decode caches -------------------------------------------------------------
    def cache_specs(self, cache_tree, batch: int) -> Any:
        cfg = self.cfg
        batch_ok = batch % self.dp_size == 0
        dp = self.dp_axes

        def spec(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            # caches may carry 1 or 2 leading layer dims ((L,...) or (L/g,g,...))
            def with_lead(*tail):
                lead = (None,) * (leaf.ndim - len(tail))
                return self.ns(*(lead + tail))

            if name in ("k_loc", "v_loc"):  # (..., B, W, nkv, hd) ring
                kv_ax = self._model(cfg.n_kv_heads)
                if batch_ok:
                    return with_lead(dp, None, kv_ax, None)
                return with_lead(None, dp, kv_ax, None)
            if name in ("k", "v", "k_glob", "v_glob"):  # (..., B, S, nkv, hd)
                kv_ax = self._model(cfg.n_kv_heads)
                if batch_ok:
                    # kv-heads not TP-divisible → context-shard the sequence
                    # over "model" instead (flash-decode style psum softmax)
                    seq_ax = None if kv_ax else "model"
                    return with_lead(dp, seq_ax, kv_ax, None)
                seq_axes = dp + (("model",) if kv_ax is None else ())
                return with_lead(None, seq_axes, kv_ax, None)
            if name == "state":  # (..., B, H, N, P)
                if batch_ok:
                    return with_lead(dp, self._model(cfg.n_ssm_heads), None, None)
                return with_lead(None, self._model(cfg.n_ssm_heads), None, None)
            if name == "conv":  # (..., B, K-1, C)
                if batch_ok:
                    return with_lead(dp, None, None)
                return self.ns()
            return self.ns()  # pos

        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
        return jax.tree_util.tree_unflatten(
            treedef, [spec(p, l) for p, l in flat]
        )

    def replicated(self) -> NamedSharding:
        return self.ns()
