"""Mamba-2 SSD (state-space duality) blocks [arXiv:2405.21060].

The SSD layer computes, per head h with scalar decay a_t = exp(Δt·A_h):

    s_t = a_t · s_{t−1} + Δt · B_t ⊗ x_t          (state  N×P)
    y_t = C_t · s_t + D_h · x_t

Chunked algorithm (the paper's Listing 1, matmul-rich → MXU-friendly):
split the sequence into chunks of length L; within a chunk the output is an
attention-like matmul with a decay-weighted lower-triangular mask; across
chunks a short scan carries the (N, P) state.  This *is* the paper's
(Cresson) streaming idea along time: bounded state, region-by-region.

Shapes: x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N) with G groups
broadcast over heads, D (H,).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def ssd_reference(x, dt, A, Bm, Cm, D) -> jnp.ndarray:
    """Step-by-step recurrence oracle (slow, for tests)."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    a = jnp.exp(dt * A[None, None, :])  # (B,S,H)

    def step(state, inp):
        xt, at, dtt, bt, ct = inp  # (B,H,P),(B,H),(B,H),(B,H,N),(B,H,N)
        state = state * at[..., None, None] + (
            dtt[..., None, None] * bt[..., :, None] * xt[..., None, :]
        )  # (B,H,N,P)
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    init = jnp.zeros((Bsz, H, N, P), jnp.float32)
    xs = (
        x.swapaxes(0, 1).astype(jnp.float32),
        a.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        Bh.swapaxes(0, 1).astype(jnp.float32),
        Ch.swapaxes(0, 1).astype(jnp.float32),
    )
    _, ys = lax.scan(step, init, xs)
    y = ys.swapaxes(0, 1) + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int = 256,
                initial_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Chunked SSD; S must divide by ``chunk``."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc, L = S // chunk, chunk
    rep = H // G

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, L, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, L, H).astype(f32)
    Bc = jnp.repeat(Bm, rep, axis=2).reshape(Bsz, nc, L, H, N).astype(f32)
    Cc = jnp.repeat(Cm, rep, axis=2).reshape(Bsz, nc, L, H, N).astype(f32)

    loga = dtc * A[None, None, None, :]  # (B,nc,L,H) log decay per step
    cum = jnp.cumsum(loga, axis=2)  # inclusive cumulative log decay

    # ---- intra-chunk (attention-like, causal) -----------------------------
    # score[i,j] = C_i·B_j · exp(cum_i − cum_j) · Δt_j   for j ≤ i
    cb = jnp.einsum("bclhn,bcmhn->bchlm", Cc, Bc)  # (B,nc,H,L,L)
    ii = cum.transpose(0, 1, 3, 2)[..., :, None]  # (B,nc,H,L,1)
    jj = cum.transpose(0, 1, 3, 2)[..., None, :]
    decay = jnp.exp(ii - jj)
    causal_mask = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(causal_mask, cb * decay, 0.0) * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", w, xc)

    # ---- chunk states ------------------------------------------------------
    # S_c = Σ_j exp(cum_L − cum_j)·Δt_j · B_j ⊗ x_j
    last = cum[:, :, -1:, :]  # (B,nc,1,H)
    decay_to_end = jnp.exp(last - cum)  # (B,nc,L,H)
    contrib = (decay_to_end * dtc)[..., None] * Bc  # (B,nc,L,H,N)
    S_c = jnp.einsum("bclhn,bclhp->bchnp", contrib, xc)  # (B,nc,H,N,P)

    # ---- inter-chunk state recurrence -------------------------------------
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H)

    def carry_step(state, inp):
        s_c, dec = inp  # (B,H,N,P), (B,H)
        new = state * dec[..., None, None] + s_c
        return new, state  # emit state *entering* the chunk

    init = (
        jnp.zeros((Bsz, H, N, P), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )
    final_state, prev_states = lax.scan(
        carry_step, init, (S_c.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    prev = prev_states.swapaxes(0, 1)  # (B,nc,H,N,P) state entering each chunk

    # ---- inter-chunk output: y_i += C_i · (exp(cum_i) · prev) --------------
    c_weighted = Cc * jnp.exp(cum)[..., None]
    y_inter = jnp.einsum("bclhn,bchnp->bclhp", c_weighted, prev)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + D[None, None, :, None] * x.astype(f32)
    y = y.astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(state, x, dt, A, Bm, Cm, D) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrent update.  state: (B,H,N,P); x: (B,H,P);
    dt: (B,H); Bm/Cm: (B,G,N).  Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(dt.astype(jnp.float32) * A[None, :])
    state = state * a[..., None, None] + (
        dt.astype(jnp.float32)[..., None, None]
        * Bh[..., :, None]
        * x.astype(jnp.float32)[..., None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state) + D[None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), state


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (B,S,C); w: (C,K).  With ``cache``
    ((B,K−1,C), decode) returns (y, new_cache)."""
    K = w.shape[-1]
    if cache is not None:
        xin = jnp.concatenate([cache, x], axis=1)  # (B, K-1+S, C)
        new_cache = xin[:, -(K - 1):, :]
    else:
        xin = jnp.pad(x, [(0, 0), (K - 1, 0), (0, 0)])
        new_cache = xin[:, -(K - 1):, :]
    # y_t = Σ_k w_k · x_{t−K+1+k}
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        y = y + xin[:, k : k + S, :].astype(jnp.float32) * w[None, None, :, k]
    return jax.nn.silu(y).astype(x.dtype), new_cache
