"""Transformer building blocks: norms, RoPE, GQA attention, gated MLPs.

All functions are pure (params in, arrays out) and shape-polymorphic over
batch/sequence so the same code path serves train, prefill and decode.
Attention computes scores/softmax in float32 regardless of activation dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: Optional[jnp.ndarray]) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def nonparam_layernorm(x: jnp.ndarray) -> jnp.ndarray:
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)


def norm(x: jnp.ndarray, scale: Optional[jnp.ndarray], kind: str) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, scale)
    if kind == "nonparam_ln":
        return nonparam_layernorm(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (S,) int32 (batch-shared)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions.astype(jnp.float32)[:, None] * freqs  # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _mask_bias(
    q_pos: jnp.ndarray,  # (Sq,)
    kv_pos: jnp.ndarray,  # (Skv,)
    causal: bool,
    window: Optional[int],
    is_global,  # traced bool or python bool — select sliding vs full
) -> jnp.ndarray:
    """(1, 1, Sq, Skv) additive bias (0 / -inf).

    Positions are 1-D (batch-independent) on purpose: a (B,·,Sq,Skv) mask
    would materialize a batch-replicated O(B·S²) tensor — at train_4k that is
    a 16 GiB/device buffer (measured), vs 64 MiB for the shared mask.
    """
    dq = q_pos[:, None]
    dk = kv_pos[None, :]
    ok = jnp.ones((dq.shape[0], dk.shape[1]), bool)
    if causal:
        ok = ok & (dk <= dq)
    if window is not None:
        win_ok = ok & (dk > dq - window)
        ok = jnp.where(jnp.asarray(is_global), ok, win_ok)
    return jnp.where(ok, 0.0, -1e30)[None, None, :, :].astype(jnp.float32)


def repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, D) → (B, S, Hkv·groups, D)."""
    if groups == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d)).reshape(
        b, s, h * groups, d
    )


def naive_attention(q, k, v, q_pos, kv_pos, causal, window, is_global,
                    softcap: Optional[float] = None) -> jnp.ndarray:
    """Grouped-query attention without materializing the GQA-expanded cache.

    q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D) with Hq = Hkv·G.  The expanded
    (B,Skv,Hq,D) tensor never exists — at decode_32k that buffer alone was
    2·G× the cache (measured 139% HBM on gemma3) — the group axis lives only
    in the scores einsum.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    # f32 accumulation WITHOUT casting inputs: .astype(f32) on a (B,S,Hkv,D)
    # cache materializes a full f32 copy (measured 6 GiB/device at decode_32k)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + _mask_bias(q_pos, kv_pos, causal, window, is_global)[:, :, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)


def blockwise_attention(
    q, k, v, q_pos, kv_pos, causal, window, is_global,
    block_q: int = 1024, block_k: int = 1024,
) -> jnp.ndarray:
    """Flash-style two-level scan: O(Sq·block_k) live memory instead of
    O(Sq·Skv).  Mandatory for the 32k/500k shapes; numerically the standard
    running-max/denominator online softmax (float32 accumulators).  Grouped
    GQA layout (no KV expansion), like naive_attention."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    if Sq % block_q or Skv % block_k:
        return naive_attention(q, k, v, q_pos, kv_pos, causal, window, is_global)
    nq, nk = Sq // block_q, Skv // block_k
    scale = 1.0 / math.sqrt(D)

    qb = q.reshape(B, nq, block_q, Hkv, G, D)
    qpb = q_pos.reshape(nq, block_q)
    kb = k.reshape(B, nk, block_k, Hkv, D)
    vb = v.reshape(B, nk, block_k, Hkv, D)
    kpb = kv_pos.reshape(nk, block_k)

    @jax.checkpoint
    def q_step(_, qi):
        # rematerialized per q-block: without this, the outer scan's backward
        # stacks the inner scan's (nk, B, Hkv, G, bq, D) f32 residuals across
        # nq blocks — measured 12 GiB/device on internvl2 train_4k
        qq, qp = qi  # (B, bq, Hkv, G, D), (bq,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kk, vv, kp = ki
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qq, kk, preferred_element_type=jnp.float32
            ) * scale
            s = s + _mask_bias(qp, kp, causal, window, is_global)[:, :, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vv.dtype), vv,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, G, block_q), -jnp.inf, jnp.float32),
            jnp.zeros((B, Hkv, G, block_q), jnp.float32),
            jnp.zeros((B, Hkv, G, block_q, D), jnp.float32),
        )
        (m, l, acc), _ = lax.scan(
            kv_step, init,
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpb),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, Hkv, G, bq, D)
        return None, jnp.moveaxis(out, 3, 1)  # (B, bq, Hkv, G, D)

    _, outs = lax.scan(
        q_step, None, (qb.swapaxes(0, 1), qpb)
    )  # (nq, B, bq, Hkv, G, D)
    return outs.swapaxes(0, 1).reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(q, k, v, q_pos, kv_pos, *, causal, window=None, is_global=False,
              softcap=None, blockwise_threshold: int = 4096) -> jnp.ndarray:
    """Dispatch naive vs blockwise on the score-matrix size.
    q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D) — grouped GQA, no KV expansion."""
    if q.shape[1] * k.shape[1] > blockwise_threshold * blockwise_threshold:
        return blockwise_attention(q, k, v, q_pos, kv_pos, causal, window, is_global)
    return naive_attention(q, k, v, q_pos, kv_pos, causal, window, is_global, softcap)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp(x, wg, wu, wd, kind: str):
    """Gated (swiglu/geglu) or plain gelu MLP; weights (d,f),(d,f),(f,d)."""
    if kind == "swiglu":
        h = jax.nn.silu(x @ wg) * (x @ wu)
    elif kind == "geglu":
        h = jax.nn.gelu(x @ wg, approximate=True) * (x @ wu)
    elif kind == "gelu":
        h = jax.nn.gelu(x @ wg, approximate=True)
    else:
        raise ValueError(kind)
    return h @ wd
