"""Mixture-of-Experts layer with group-local sort-based top-k dispatch.

TPU adaptation: GPU MoE kernels scatter tokens to experts with atomics /
grouped GEMMs.  Here dispatch is GShard-style: tokens are organized into
``n_groups`` groups (= the data-parallel shards) and the sort / rank /
gather / scatter steps run *device-local under shard_map* — the XLA
auto-partitioner handles batched gathers poorly (measured: replicate-then-
reshard fallbacks materializing 5–8 GiB buffers at prefill_32k), while
inside shard_map they are plain local ops with zero collectives.  The only
cross-device traffic is the intended expert-parallel exchange around the
expert FFN einsums (buffers re-sharded group-axis → expert-axis), which
XLA lowers to all-to-alls — measured in §Roofline and targeted by the MoE
hillclimb.

Capacity C = ⌈cf · T_g · k / E⌉ per group; overflow tokens are dropped
(standard capacity-factor semantics) and pass through the residual stream.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def moe_capacity(tokens: int, k: int, n_experts: int, cf: float) -> int:
    c = int(math.ceil(cf * tokens * k / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def default_n_groups(T: int) -> int:
    """Groups = dp shards (device-local dispatch); 1 when unconfigured."""
    from repro.models.sharding import dp_size

    g = dp_size()
    while g > 1 and T % g:
        g //= 2
    return max(1, g)


# ---------------------------------------------------------------------------
# group-local dispatch / combine (pure, batched over the group dim; run
# either directly (tests) or device-local under shard_map)
# ---------------------------------------------------------------------------
def _dispatch(xg, expert_ids, gate_vals, *, E, C, k):
    """xg (G,Tg,d); expert_ids/gate_vals (G,Tg,k) →
    xe (G,E,C,d), buf_tok (G,E·C), gate_slot (G,E·C), counts (G,E), keep."""
    G, Tg, d = xg.shape
    flat_e = expert_ids.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    gidx = jnp.arange(G)[:, None]
    counts = jnp.zeros((G, E), jnp.int32).at[gidx, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts
    rank = jnp.arange(Tg * k, dtype=jnp.int32)[None] - jnp.take_along_axis(
        starts, sorted_e, axis=-1
    )
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)
    tok_of_assign = order // k
    buf_tok = (
        jnp.full((G, E * C + 1), Tg, jnp.int32).at[gidx, slot].set(tok_of_assign)
    )[:, : E * C]
    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(x_pad, buf_tok[..., None], axis=1)
    gate_sorted = jnp.take_along_axis(gate_vals.reshape(G, Tg * k), order, axis=-1)
    gate_slot = (
        jnp.zeros((G, E * C + 1), jnp.float32).at[gidx, slot].set(gate_sorted)
    )[:, : E * C]
    return xe.reshape(G, E, C, d), buf_tok, gate_slot, counts, keep


def _combine(ye_flat, buf_tok, gate_slot, *, Tg):
    """ye_flat (G,E·C,d) f32 → y (G,Tg,d) f32 (weighted scatter-add)."""
    G, EC, d = ye_flat.shape
    gidx = jnp.arange(G)[:, None]
    contrib = ye_flat * gate_slot[..., None]
    return (
        jnp.zeros((G, Tg + 1, d), jnp.float32)
        .at[gidx[..., None], buf_tok]
        .add(contrib)
    )[:, :Tg]


def _maybe_shard_map(fn, n_outs, *args, group_arity):
    """Run fn device-local over the dp axes when a mesh is configured."""
    from repro.models.sharding import batch_axes, current_mesh, dp_size

    mesh = current_mesh()
    axes = batch_axes()
    G = args[0].shape[0]
    if mesh is None or axes is None or G % max(1, dp_size()):
        return fn(*args)
    spec = P(axes)
    in_specs = tuple(spec for _ in args)
    out_specs = tuple(spec for _ in range(n_outs)) if n_outs > 1 else spec
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)(
        *args
    )


def moe_layer(
    x: jnp.ndarray,  # (T, d)
    router_w: jnp.ndarray,  # (d, E)
    wg: jnp.ndarray,  # (E, d, f)
    wu: Optional[jnp.ndarray],  # (E, d, f) or None for non-gated
    wd: jnp.ndarray,  # (E, f, d)
    k: int,
    capacity_factor: float = 1.25,
    mlp_type: str = "swiglu",
    n_groups: Optional[int] = None,
):
    """Returns (y (T, d), aux) with aux = load-balancing stats."""
    from repro.models.sharding import constrain_expert_buffers, constrain_groups

    T, d = x.shape
    E = router_w.shape[-1]
    G = n_groups or default_n_groups(T)
    Tg = T // G
    C = moe_capacity(Tg, k, E, capacity_factor)

    xg = constrain_groups(x.reshape(G, Tg, d))
    logits = jnp.einsum(
        "gtd,de->gte", xg, router_w.astype(xg.dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, k)  # (G,Tg,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    xe, buf_tok, gate_slot, counts, keep = _maybe_shard_map(
        functools.partial(_dispatch, E=E, C=C, k=k), 5,
        xg, expert_ids, gate_vals, group_arity=3,
    )
    # group-sharded → expert-sharded: the expert-parallel all-to-all
    xe = constrain_expert_buffers(xe)

    h = jnp.einsum("gecd,edf->gecf", xe, wg.astype(xe.dtype))
    if mlp_type == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, wu.astype(xe.dtype))
    elif mlp_type == "geglu":
        h = jax.nn.gelu(h, approximate=True) * jnp.einsum(
            "gecd,edf->gecf", xe, wu.astype(xe.dtype)
        )
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain_expert_buffers(h)
    ye = constrain_expert_buffers(
        jnp.einsum("gecf,efd->gecd", h, wd.astype(xe.dtype))
    )

    # expert-sharded → group-sharded (all-to-all back), then local combine
    ye_flat = constrain_groups(
        ye.reshape(G, E * C, d).astype(jnp.float32)
    )
    y = _maybe_shard_map(
        functools.partial(_combine, Tg=Tg), 1,
        ye_flat, buf_tok, gate_slot, group_arity=3,
    )
    y = constrain_groups(y)

    # aux: routed fraction per expert & dropped fraction (load-balance signals)
    load = counts.astype(jnp.float32).sum(0) / (T * k)
    dropped = 1.0 - keep.mean()
    importance = probs.mean((0, 1))
    aux_loss = E * jnp.sum(load * importance)  # switch-style balance loss
    return y.reshape(T, d).astype(x.dtype), {
        "moe_load": load,
        "moe_dropped": dropped,
        "moe_aux_loss": aux_loss,
    }
