"""Input specs per (architecture × shape): ShapeDtypeStructs for the dry-run
(no allocation) and synthetic batches for smoke tests / examples.

Modality frontends are STUBS per the assignment: ``[vlm]`` receives
precomputed patch embeddings, ``[audio]`` precomputed frame embeddings —
``input_specs`` reflects that contract.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _split_vlm_seq(cfg: ModelConfig, seq: int) -> Tuple[int, int]:
    f = min(cfg.frontend_tokens, seq // 2)
    return f, seq - f


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    emb_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "vlm":
        F, T = _split_vlm_seq(cfg, S)
        return {
            "embeds": jax.ShapeDtypeStruct((B, F, cfg.d_model), emb_dt),
            "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family in ("vlm",):
        F, T = _split_vlm_seq(cfg, S)
        # prefill over the text part; frontend embeds enter via forward()
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        emb_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode step: one new token against a cache of seq_len."""
    from repro.models import lm

    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache,
    }


def synth_train_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """Concrete random batch (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    emb_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out = {}
    if cfg.family == "vlm":
        F, T = _split_vlm_seq(cfg, seq)
        out["embeds"] = jnp.asarray(
            rng.normal(size=(batch, F, cfg.d_model)).astype(np.float32), emb_dt
        )
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, T)), jnp.int32
        )
        labels = rng.integers(0, cfg.vocab_size, size=(batch, seq))
        labels[:, :F] = -100  # no loss on image positions
        out["labels"] = jnp.asarray(labels, jnp.int32)
    elif cfg.family == "audio":
        out["embeds"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32), emb_dt
        )
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32
        )
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32
        )
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32
        )
    return out
