"""LM model zoo: layers, SSD, MoE, and the config-driven model."""
from repro.models import layers, ssm, moe, lm, inputs

__all__ = ["layers", "ssm", "moe", "lm", "inputs"]
