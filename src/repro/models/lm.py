"""The LM model zoo: one functional implementation covering dense / MoE /
SSM / hybrid / VLM / audio architectures, driven entirely by ``ModelConfig``.

Params are a plain pytree; per-layer params are stacked on a leading L axis
and the layer stack runs under ``lax.scan`` (+ ``jax.checkpoint``), so HLO
size and compile time are depth-independent and remat policy is uniform.

Entry points:
  init_params(cfg, key)                  → params
  forward(params, cfg, tokens/embeds)    → final hidden states
  loss_fn(params, cfg, batch)            → (loss, metrics)   [train_step body]
  prefill(params, cfg, tokens)           → (logits_last, cache)
  decode_step(params, cfg, cache, ...)   → (logits, cache)   [serve_step body]
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.sharding import constrain_batch, constrain_act

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _dense_block_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    s: Dict[str, Tuple[int, ...]] = {}
    if cfg.family != "ssm":
        s.update(
            wq=(d, nh * hd), wk=(d, nkv * hd), wv=(d, nkv * hd), wo=(nh * hd, d)
        )
        if cfg.attn_bias:
            s.update(bq=(nh * hd,), bk=(nkv * hd,), bv=(nkv * hd,))
    if cfg.family == "moe":
        E = cfg.n_experts
        s.update(router=(d, E), mwg=(E, d, f), mwd=(E, f, d))
        if cfg.mlp_type in ("swiglu", "geglu"):
            s.update(mwu=(E, d, f))
    elif cfg.family != "ssm":
        s.update(wg=(d, f), wd_=(f, d))
        if cfg.mlp_type in ("swiglu", "geglu"):
            s.update(wu=(d, f))
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.d_inner
        H = cfg.n_ssm_heads
        G, N, K = 1, cfg.ssm_state, cfg.conv_kernel
        s.update(
            swz=(d, di), swx=(d, di), swB=(d, G * N), swC=(d, G * N), swdt=(d, H),
            sconv=(di + 2 * G * N, K), sA_log=(H,), sD=(H,), sdt_bias=(H,),
            snorm=(di,), sout=(di, d),
        )
    if cfg.norm_type != "nonparam_ln":
        s.update(norm1=(d,), norm2=(d,))
    return s


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    d, V, Ln = cfg.d_model, cfg.vocab_size, cfg.n_layers
    keys = jax.random.split(key, 8)

    def nrm(k, shape, scale=None):
        scale = scale if scale is not None else 0.02
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    Vp = cfg.vocab_padded
    params: Params = {"embed": nrm(keys[0], (Vp, d))}
    if not cfg.tie_embeddings:
        params["lm_head"] = nrm(keys[1], (d, Vp))
    if cfg.norm_type != "nonparam_ln":
        params["final_norm"] = jnp.zeros((d,), dt)

    shapes = _dense_block_shapes(cfg)
    bkeys = jax.random.split(keys[2], len(shapes))
    blocks: Params = {}
    for (name, shape), k in zip(sorted(shapes.items()), bkeys):
        full = (Ln,) + shape
        if name.startswith("norm") or name in ("snorm",):
            blocks[name] = jnp.zeros(full, dt)
        elif name in ("bq", "bk", "bv", "sdt_bias"):
            blocks[name] = jnp.zeros(full, dt)
        elif name == "sA_log":
            # A ∈ [-1.6, -0.4]: log(-A) stored for positivity
            blocks[name] = jnp.log(
                jnp.linspace(0.5, 1.5, cfg.n_ssm_heads, dtype=jnp.float32)
            )[None, :].repeat(Ln, 0).astype(jnp.float32)
        elif name == "sD":
            blocks[name] = jnp.ones(full, dt)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            blocks[name] = nrm(k, full, scale=1.0 / math.sqrt(max(1, fan_in)))
    params["blocks"] = blocks
    return params


def grouped_decode(cfg: ModelConfig) -> bool:
    """Static local/global layer grouping for decode (sliding-window archs
    whose pattern divides the stack): caches are allocated (L/g, g, ...)."""
    g = cfg.global_interval
    return bool(
        cfg.sliding_window is not None and g and cfg.n_layers % g == 0
        and cfg.family != "ssm"
    )


def layer_flags(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) bool — True where the layer uses *global* attention."""
    if cfg.sliding_window is None or cfg.global_interval is None:
        return jnp.ones((cfg.n_layers,), bool)
    idx = np.arange(cfg.n_layers)
    return jnp.asarray((idx % cfg.global_interval) == cfg.global_interval - 1)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _attn(
    h, blk, cfg: ModelConfig, positions, is_global,
    cache_kv=None, pos=None, ring: bool = False,
):
    """Returns (out, new_cache_kv or None).  cache_kv = (k,v): (B,Smax,nkv,hd)."""
    B, S, d = h.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = h @ blk["wq"]
    k = h @ blk["wk"]
    v = h @ blk["wv"]
    if cfg.attn_bias:
        q, k, v = q + blk["bq"], k + blk["bk"], v + blk["bv"]
    q = q.reshape(B, S, nh, hd)
    k = k.reshape(B, S, nkv, hd)
    v = v.reshape(B, S, nkv, hd)
    if cfg.use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    if cache_kv is None:
        out = L.attention(
            q, k, v, positions, positions,
            causal=cfg.causal, window=cfg.sliding_window, is_global=is_global,
            softcap=cfg.logit_softcap,
            blockwise_threshold=cfg.blockwise_threshold,
        )
        new_cache = (k, v)
    elif ring:
        # sliding-window layer with a RING cache of Wa = min(window, max_seq)
        # slots: slot i holds the newest position ≡ i (mod Wa).  Allocation
        # and reads shrink by S/Wa (gemma3 decode_32k: 32×) and stay local —
        # no dynamic slicing across the sharded sequence dim.
        ck, cv = cache_kv  # (B, Wa, nkv, hd)
        Wa = ck.shape[1]
        rpos = pos % Wa
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, rpos, 0, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, rpos, 0, 0))
        slots = jnp.arange(Wa, dtype=jnp.int32)
        kv_pos = pos - ((pos - slots) % Wa)  # unwritten → i−Wa, window-masked
        out = L.attention(
            q, ck, cv, positions, kv_pos,
            causal=True, window=cfg.sliding_window, is_global=False,
            softcap=cfg.logit_softcap,
            blockwise_threshold=cfg.blockwise_threshold,
        )
        new_cache = (ck, cv)
    else:
        ck, cv = cache_kv  # (B, Smax, nkv, hd)
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
        Smax = ck.shape[1]
        kv_pos = jnp.arange(Smax, dtype=jnp.int32)
        # unwritten cache slots are masked by the causal test vs q position
        out = L.attention(
            q, ck, cv, positions, kv_pos,
            causal=True, window=cfg.sliding_window, is_global=is_global,
            softcap=cfg.logit_softcap,
            blockwise_threshold=cfg.blockwise_threshold,
        )
        new_cache = (ck, cv)
    out = out.reshape(B, S, nh * hd) @ blk["wo"]
    return out, new_cache


def _mlp(h, blk, cfg: ModelConfig):
    if cfg.family == "moe":
        B, S, d = h.shape
        y, aux = MOE.moe_layer(
            h.reshape(B * S, d),
            blk["router"], blk["mwg"], blk.get("mwu"), blk["mwd"],
            k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor,
            mlp_type=cfg.mlp_type,
        )
        return y.reshape(B, S, d), aux
    wg, wd = blk["wg"], blk["wd_"]
    wu = blk.get("wu")
    return L.mlp(h, wg, wu if wu is not None else wg, wd, cfg.mlp_type), {}


def _ssm(h, blk, cfg: ModelConfig, conv_cache=None, ssm_state=None):
    """Mamba2 (SSD) mixer.  Returns (out, (new_conv_cache, new_state))."""
    B, S, d = h.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    G = 1
    z = h @ blk["swz"]
    x = h @ blk["swx"]
    Bm = h @ blk["swB"]
    Cm = h @ blk["swC"]
    dt = jax.nn.softplus((h @ blk["swdt"]).astype(jnp.float32) + blk["sdt_bias"])
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc, new_conv = SSM.causal_conv1d(xbc, blk["sconv"], conv_cache)
    x, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    A = -jnp.exp(blk["sA_log"].astype(jnp.float32))
    D = blk["sD"].astype(jnp.float32)
    if ssm_state is None:
        chunk = min(cfg.ssm_chunk, S)
        while S % chunk:  # largest divisor ≤ configured chunk (smoke shapes)
            chunk -= 1
        y, new_state = SSM.ssd_chunked(
            x.reshape(B, S, H, P), dt,
            A, Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N), D,
            chunk=chunk, return_state=True,
        )
    else:
        y, new_state = SSM.ssd_decode_step(
            ssm_state, x.reshape(B, H, P), dt.reshape(B, H),
            A, Bm.reshape(B, G, N), Cm.reshape(B, G, N), D,
        )
        y = y.reshape(B, 1, H, P)
    y = y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = L.rmsnorm(y, blk["snorm"])
    return y @ blk["sout"], (new_conv, new_state)


def _block(h, blk, cfg: ModelConfig, positions, is_global, caches, pos,
           ring: bool = False):
    """One transformer block; caches is a dict possibly holding kv / conv /
    state entries (None values in train/prefill-without-cache paths)."""
    new_caches = {}
    aux = {}
    nrm = lambda x, sc: L.norm(x, sc, cfg.norm_type)
    sc1 = blk.get("norm1")
    sc2 = blk.get("norm2")

    if cfg.family == "ssm":
        mixer_in = nrm(h, sc1)
        out, (cv, st) = _ssm(mixer_in, blk, cfg, caches.get("conv"), caches.get("state"))
        new_caches.update(conv=cv, state=st)
        h = h + out
        return h, new_caches, aux

    mixer_in = nrm(h, sc1)
    if cfg.family == "hybrid":
        a_out, kv = _attn(mixer_in, blk, cfg, positions, is_global,
                          caches.get("kv"), pos, ring)
        s_out, (cv, st) = _ssm(mixer_in, blk, cfg, caches.get("conv"),
                               caches.get("state"))
        out = 0.5 * (a_out + s_out)
        new_caches.update(kv=kv, conv=cv, state=st)
    else:
        out, kv = _attn(mixer_in, blk, cfg, positions, is_global,
                        caches.get("kv"), pos, ring)
        new_caches.update(kv=kv)
    h = h + out
    mlp_out, aux = _mlp(nrm(h, sc2), blk, cfg)
    h = h + mlp_out
    return h, new_caches, aux


# ---------------------------------------------------------------------------
# forward (train / encode)
# ---------------------------------------------------------------------------
def embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    remat: str = "nothing",
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward → (hidden (B,S,d), aux)."""
    if embeds is None:
        h = embed_tokens(params, cfg, tokens)
    elif tokens is not None:
        h = jnp.concatenate([embeds.astype(_dtype(cfg)),
                             embed_tokens(params, cfg, tokens)], axis=1)
    else:
        h = embeds.astype(_dtype(cfg))
    h = constrain_batch(h)
    B, S, d = h.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    flags = layer_flags(cfg)

    def body(carry, xs):
        blk, is_global = xs
        hh, aux_sum = carry
        hh, _, aux = _block(hh, blk, cfg, positions, is_global, {}, None)
        hh = constrain_act(hh)
        aux_l = aux.get("moe_aux_loss", jnp.zeros((), jnp.float32))
        return (hh, aux_sum + aux_l), None

    body_fn = body
    if remat == "nothing":
        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    (h, aux_loss), _ = lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                                (params["blocks"], flags),
                                unroll=min(cfg.scan_unroll, cfg.n_layers))
    h = L.norm(h, params.get("final_norm"), cfg.norm_type)
    return h, {"moe_aux_loss": aux_loss / max(1, cfg.n_layers)}


def lm_head_weight(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def mask_padded_logits(logits: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Padded vocab columns must not contribute to softmax/argmax."""
    Vp = logits.shape[-1]
    if Vp == cfg.vocab_size:
        return logits
    col = jnp.arange(Vp) >= cfg.vocab_size
    return jnp.where(col, -1e30, logits)


def chunked_ce_loss(
    h: jnp.ndarray,  # (B,S,d)
    labels: jnp.ndarray,  # (B,S) int32, -100 = ignore
    w: jnp.ndarray,  # (d,V)
    chunk: int = 512,
    ignore: int = -100,
    real_vocab: int = -1,
):
    """Cross-entropy without materializing (B,S,V): scan over S-chunks with
    rematerialized logits (checkpoint)."""
    B, S, d = h.shape
    V = w.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback (smoke tests with odd S)
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def step(carry, xs):
        tot, cnt = carry
        hh, ll = xs
        hh = constrain_batch(hh)
        logits = (hh.astype(jnp.float32) @ w.astype(jnp.float32))
        if real_vocab > 0 and real_vocab < V:
            logits = jnp.where(jnp.arange(V) >= real_vocab, -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1
        )[..., 0]
        mask = (ll != ignore).astype(jnp.float32)
        tot = tot + ((logz - gold) * mask).sum()
        cnt = cnt + mask.sum()
        return (tot, cnt), None

    (tot, cnt), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            remat: str = "nothing") -> Tuple[jnp.ndarray, Dict]:
    """batch: {tokens (B,S)} and/or {embeds (B,F,d)}, {labels (B,S_total)}."""
    h, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"), remat=remat,
    )
    loss = chunked_ce_loss(h, batch["labels"], lm_head_weight(params, cfg),
                           chunk=cfg.ce_chunk, real_vocab=cfg.vocab_size)
    total = loss + 0.01 * aux.get("moe_aux_loss", 0.0)
    return total, {"ce_loss": loss, **aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Dict:
    dt = dtype or _dtype(cfg)
    Ln = cfg.n_layers
    grouped = grouped_decode(cfg)
    gi = cfg.global_interval if grouped else 1
    lead = (Ln // gi, gi) if grouped else (Ln,)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family != "ssm":
        nkv, hd = cfg.n_kv_heads, cfg.head_dim
        if grouped:
            # local layers keep a ring of min(window, max_seq) slots; only
            # the one global layer per group stores the full sequence
            Wa = min(cfg.sliding_window, max_seq)
            cache["k_loc"] = jnp.zeros((Ln // gi, gi - 1, batch, Wa, nkv, hd), dt)
            cache["v_loc"] = jnp.zeros((Ln // gi, gi - 1, batch, Wa, nkv, hd), dt)
            cache["k_glob"] = jnp.zeros((Ln // gi, 1, batch, max_seq, nkv, hd), dt)
            cache["v_glob"] = jnp.zeros((Ln // gi, 1, batch, max_seq, nkv, hd), dt)
        else:
            cache["k"] = jnp.zeros(lead + (batch, max_seq, nkv, hd), dt)
            cache["v"] = jnp.zeros(lead + (batch, max_seq, nkv, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        P = di // H
        G = 1
        cache["conv"] = jnp.zeros(lead + (batch, cfg.conv_kernel - 1, di + 2 * G * N), dt)
        cache["state"] = jnp.zeros(lead + (batch, H, N, P), jnp.float32)
    return cache


def _layer_caches(cfg, cache):
    out = {}
    if "k" in cache:
        out["kv"] = (cache["k"], cache["v"])
    if "conv" in cache:
        out["conv"] = cache["conv"]
        out["state"] = cache["state"]
    return out


def _store(cfg, new_layer_caches):
    out = {}
    if "kv" in new_layer_caches and new_layer_caches["kv"] is not None:
        out["k"], out["v"] = new_layer_caches["kv"]
    if new_layer_caches.get("conv") is not None:
        out["conv"] = new_layer_caches["conv"]
    if new_layer_caches.get("state") is not None:
        out["state"] = new_layer_caches["state"]
    return out


def decode_step(
    params: Params, cfg: ModelConfig, cache: Dict, tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, Dict]:
    """tokens: (B, 1) → (logits (B, 1, V), updated cache).  One new token
    against a cache of ``max_seq`` (the decode_32k / long_500k step)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    h = constrain_batch(embed_tokens(params, cfg, tokens))
    positions = (pos + jnp.arange(1, dtype=jnp.int32)).astype(jnp.int32)
    flags = layer_flags(cfg)

    def _run_block(h, blk, is_global, lcache):
        caches = {}
        if "k" in lcache:
            caches["kv"] = (lcache["k"], lcache["v"])
        if "conv" in lcache:
            caches["conv"] = lcache["conv"]
            caches["state"] = lcache["state"]
        hh, ncs, _ = _block(h, blk, cfg, positions, is_global, caches, pos)
        return constrain_batch(hh), _store(cfg, ncs)

    layer_cache = {k: v for k, v in cache.items() if k != "pos"}
    g = cfg.global_interval
    if grouped_decode(cfg):
        # super-block scan: each step = g layers with STATIC local/global
        # flags (…local×(g−1), global).  Local layers use ring caches of
        # window slots; caches are allocated pre-grouped (no reshape, so the
        # donated buffers alias through the scan).
        regroup = lambda a: a.reshape((cfg.n_layers // g, g) + a.shape[1:])
        blocks_g = jax.tree.map(regroup, params["blocks"])

        def body(h, xs):
            blk_g, lcache_g = xs
            loc_emits, glob_emit, other_emits = [], None, []
            for j in range(g):
                blk_j = jax.tree.map(lambda a: a[j], blk_g)
                is_glob = j == g - 1
                caches = {}
                if "k_loc" in lcache_g:
                    if is_glob:
                        caches["kv"] = (lcache_g["k_glob"][0], lcache_g["v_glob"][0])
                    else:
                        caches["kv"] = (lcache_g["k_loc"][j], lcache_g["v_loc"][j])
                if "conv" in lcache_g:
                    caches["conv"] = lcache_g["conv"][j]
                    caches["state"] = lcache_g["state"][j]
                h, ncs, _ = _block(h, blk_j, cfg, positions, is_glob, caches,
                                   pos, ring=not is_glob)
                h = constrain_batch(h)
                st = _store(cfg, ncs)
                if "k" in st:
                    if is_glob:
                        glob_emit = {"k_glob": st["k"], "v_glob": st["v"]}
                    else:
                        loc_emits.append({"k_loc": st["k"], "v_loc": st["v"]})
                other_emits.append({k2: v2 for k2, v2 in st.items()
                                    if k2 in ("conv", "state")})
            out = {}
            if loc_emits:
                out["k_loc"] = jnp.stack([e["k_loc"] for e in loc_emits], 0)
                out["v_loc"] = jnp.stack([e["v_loc"] for e in loc_emits], 0)
                out["k_glob"] = glob_emit["k_glob"][None]
                out["v_glob"] = glob_emit["v_glob"][None]
            if other_emits and other_emits[0]:
                out["conv"] = jnp.stack([e["conv"] for e in other_emits], 0)
                out["state"] = jnp.stack([e["state"] for e in other_emits], 0)
            return h, out

        h, new_layer_cache = lax.scan(body, h, (blocks_g, layer_cache))
    else:
        def body(h, xs):
            blk, is_global, lcache = xs
            return _run_block(h, blk, bool(is_global) if isinstance(is_global, bool) else is_global, lcache)

        h, new_layer_cache = lax.scan(
            body, h, (params["blocks"], flags, layer_cache),
            unroll=min(cfg.scan_unroll, cfg.n_layers),
        )
    h = L.norm(h, params.get("final_norm"), cfg.norm_type)
    logits = h.astype(jnp.float32) @ lm_head_weight(params, cfg).astype(jnp.float32)
    logits = mask_padded_logits(logits, cfg)
    new_cache = dict(new_layer_cache, pos=pos + 1)
    return logits, new_cache


def prefill(
    params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
    max_seq: Optional[int] = None, remat: str = "nothing",
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence prefill → (last-position logits (B,V), cache)."""
    B, S = tokens.shape
    max_seq = max_seq or S
    h = constrain_batch(embed_tokens(params, cfg, tokens))
    positions = jnp.arange(S, dtype=jnp.int32)
    flags = layer_flags(cfg)
    dt = _dtype(cfg)

    def body(hh, xs):
        blk, is_global = xs
        out_h, ncs, _ = _block(hh, blk, cfg, positions, is_global, {}, None)
        out_h = constrain_act(out_h)
        emit = {}
        if "kv" in ncs and ncs["kv"] is not None:
            k, v = ncs["kv"]
            if max_seq > S:
                pad = [(0, 0), (0, max_seq - S), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            emit["k"], emit["v"] = k.astype(dt), v.astype(dt)
        if ncs.get("conv") is not None:
            emit["conv"] = ncs["conv"]
        if ncs.get("state") is not None:
            emit["state"] = ncs["state"]
        return out_h, emit

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat == "nothing" else body
    h, layer_cache = lax.scan(body_fn, h, (params["blocks"], flags),
                              unroll=min(cfg.scan_unroll, cfg.n_layers))
    h = L.norm(h, params.get("final_norm"), cfg.norm_type)
    last = h[:, -1, :]
    logits = last.astype(jnp.float32) @ lm_head_weight(params, cfg).astype(jnp.float32)
    logits = mask_padded_logits(logits, cfg)
    if grouped_decode(cfg):
        gi = cfg.global_interval
        layer_cache = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // gi, gi) + a.shape[1:]),
            layer_cache,
        )
        if "k" in layer_cache:
            Wa = min(cfg.sliding_window, max_seq)
            kk, vv = layer_cache.pop("k"), layer_cache.pop("v")

            s0 = max(S - Wa, 0)  # static

            def to_ring(a):  # (Lg, g-1, B, max_seq, kv, hd) → ring of Wa
                last = a[:, :, :, s0 : s0 + Wa]
                # slot for position p is p % Wa → roll by s0 mod Wa
                return jnp.roll(last, s0 % Wa, axis=3)

            layer_cache["k_loc"] = to_ring(kk[:, : gi - 1])
            layer_cache["v_loc"] = to_ring(vv[:, : gi - 1])
            layer_cache["k_glob"] = kk[:, gi - 1 :]
            layer_cache["v_glob"] = vv[:, gi - 1 :]
    cache = dict(layer_cache, pos=jnp.asarray(S, jnp.int32))
    return logits, cache
