import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on the production mesh with ShapeDtypeStruct inputs (no allocation).

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun

Per cell it records:
  * memory_analysis() of the REAL program — per-device bytes, proves fit;
  * exact HLO FLOPs / collective bytes via the loop-correction pair: the CPU
    backend's cost_analysis() counts a ``while`` body once, so we compile a
    loop-free *analysis variant* (naive attention, single CE/SSD chunk) at
    L=1 and L=2 and extrapolate  total = outer + L·(F(2) − F(1));
  * collective bytes parsed from post-SPMD HLO (same L-correction — the
    collective pattern is attention-algorithm independent).

Results are one JSON per cell; finished cells are skipped on re-run.
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cell_is_supported, get_config
from repro.launch.analysis import analyze_compiled
from repro.launch.mesh import HW, make_production_mesh
from repro.models import lm
from repro.models.inputs import (
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.models.sharding import ShardingRules, set_batch_axes
from repro.optim import adamw_init
from repro.train import build_grad_accum_train_step, build_train_step


def _opt_specs_like(rules: ShardingRules, param_specs):
    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=rules.replicated(),
        mu=param_specs,
        nu=jax.tree.map(lambda s: s, param_specs),
    )


def analysis_variant(cfg, n_layers: int):
    """Loop-free layers: every op appears in HLO with its true trip count.
    Naive attention + single CE/SSD chunk → exact FLOP/collective counts
    (those are algorithm-independent / loop-structure-independent)."""
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        ce_chunk=1 << 30,
        blockwise_threshold=1 << 30,
        ssm_chunk=1 << 30,
        scan_unroll=1 << 30,
    )


def bytes_variant(cfg, n_layers: int):
    """Unrolled layers but the REAL algorithms (blockwise attention, chunked
    CE/SSD) → HLO bytes reflect the streaming implementation's HBM traffic,
    not the naive S² materialization.  (Inner flash/CE loop bodies are still
    counted once — an optimistic "KV stream stays resident" bound, noted in
    EXPERIMENTS.md.)"""
    return dataclasses.replace(cfg, n_layers=n_layers, scan_unroll=1 << 30)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, remat: str = "nothing",
               cfg=None):
    """Build + lower + compile one cell; returns (compiled, n_devices, meta)."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = ShardingRules(mesh, cfg)
    set_batch_axes(rules.dp_axes, rules.tp, rules.dp_size, mesh=mesh,
                   seq_shard=getattr(cfg, 'seq_shard_acts', True))

    params_sds = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = rules.param_specs(params_sds)

    with mesh:
        if shape.kind == "train":
            batch_sds = train_input_specs(cfg, shape)
            bspecs = rules.batch_specs(batch_sds)
            opt_sds = jax.eval_shape(lambda: adamw_init(params_sds))
            ospecs = _opt_specs_like(rules, pspecs)
            if cfg.train_microbatches > 1:
                step = build_grad_accum_train_step(
                    cfg, cfg.train_microbatches, remat=remat
                )
            else:
                step = build_train_step(cfg, remat=remat)
            fn = jax.jit(
                step,
                in_shardings=(pspecs, ospecs, bspecs),
                out_shardings=(pspecs, ospecs, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds = prefill_input_specs(cfg, shape)
            bspecs = rules.batch_specs(batch_sds)

            if "tokens" in batch_sds:
                def prefill_fn(params, batch):
                    return lm.prefill(params, cfg, batch["tokens"])
            else:  # encoder-only: prefill = full encode + logits head
                def prefill_fn(params, batch):
                    h, _ = lm.forward(params, cfg, embeds=batch["embeds"])
                    w = lm.lm_head_weight(params, cfg)
                    return h[:, -1].astype(jnp.float32) @ w.astype(jnp.float32)

            out_sds = jax.eval_shape(prefill_fn, params_sds, batch_sds)
            if isinstance(out_sds, tuple):  # (logits, cache) → shard the cache
                cspecs = rules.cache_specs(out_sds[1], shape.global_batch)
                out_shardings = (None, cspecs)
            else:
                out_shardings = None
            fn = jax.jit(prefill_fn, in_shardings=(pspecs, bspecs),
                         out_shardings=out_shardings)
            lowered = fn.lower(params_sds, batch_sds)
        else:  # decode
            specs = decode_input_specs(cfg, shape)
            cache_sds, tok_sds = specs["cache"], specs["tokens"]
            cspecs = rules.cache_specs(cache_sds, shape.global_batch)
            tspecs = rules.batch_specs({"tokens": tok_sds})["tokens"]

            def decode_fn(params, cache, tokens):
                return lm.decode_step(params, cfg, cache, tokens)

            fn = jax.jit(
                decode_fn,
                in_shardings=(pspecs, cspecs, tspecs),
                out_shardings=(None, cspecs),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params_sds, cache_sds, tok_sds)
        compiled = lowered.compile()
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    return compiled, n_dev, meta


_CORR_KEYS = ("flops", "bytes_accessed", "transcendentals")


def _ldiff(recs, n_layers_full, get):
    v1, v2 = get(recs[0]), get(recs[1])
    per_layer = max(0.0, v2 - v1)
    return (v1 - per_layer) + n_layers_full * per_layer, per_layer


def loop_corrected_stats(arch, shape_name, multi_pod, remat, n_layers_full,
                         variant=analysis_variant):
    """Compile ``variant`` at L=b and L=2b (b = the static layer-group size,
    so grouped decode keeps its pattern); extrapolate every metric to L."""
    from repro.models.lm import grouped_decode

    base = get_config(arch)
    b = base.global_interval if (
        grouped_decode(base) and SHAPES[shape_name].kind == "decode"
    ) else 1
    recs = []
    for nl in (b, 2 * b):
        cfg = variant(base, nl)
        compiled, n_dev, _ = lower_cell(arch, shape_name, multi_pod, remat, cfg=cfg)
        stats = analyze_compiled(compiled, n_dev)
        recs.append(stats)
        del compiled
    n_blocks = n_layers_full // b
    out_cost = {}
    for k in _CORR_KEYS:
        out_cost[k], out_cost[k + "_per_layer"] = _ldiff(
            recs, n_blocks, lambda r, k=k: r["cost"][k]
        )
    c1, c2 = recs[0]["collectives"], recs[1]["collectives"]
    out_coll = {}
    for k in set(c1) | set(c2):
        out_coll[k], _ = _ldiff(
            recs, n_blocks, lambda r, k=k: r["collectives"].get(k, 0)
        )
    return out_cost, out_coll


def run_cell(arch, shape_name, multi_pod, out_dir: pathlib.Path, remat="nothing"):
    mesh_tag = "multi" if multi_pod else "single"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_path.exists():
        rec = json.loads(out_path.read_text())
        if "error" not in rec:
            print(f"[skip] {out_path.name} (done)")
            return rec
    cfg = get_config(arch)
    ok, why = cell_is_supported(cfg, SHAPES[shape_name])
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "skipped": True, "reason": why}
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[SKIP] {arch} × {shape_name} × {mesh_tag}: {why}")
        return rec
    t0 = time.time()
    try:
        compiled, n_dev, meta = lower_cell(arch, shape_name, multi_pod, remat)
        stats = analyze_compiled(compiled, n_dev)
        del compiled
        corr_cost, corr_coll = loop_corrected_stats(
            arch, shape_name, multi_pod, remat, cfg.n_layers
        )
        bytes_cost, _ = loop_corrected_stats(
            arch, shape_name, multi_pod, remat, cfg.n_layers,
            variant=bytes_variant,
        )
        corr_cost["bytes_accessed_streaming"] = bytes_cost["bytes_accessed"]
        hbm = HW["hbm_bytes"]
        mem = stats["memory"]
        per_dev = mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"] - mem["alias_bytes"]
        # TPU-equivalent footprint: minus the CPU backend's f32 copies of
        # bf16 parameters (no native bf16 on host CPUs; see analysis.py)
        adjusted = per_dev - mem["cpu_bf16_upcast_bytes"]
        rec = {
            **meta,
            "skipped": False,
            "compile_s": round(time.time() - t0, 1),
            **stats,
            "cost_corrected": corr_cost,
            "collectives_corrected": corr_coll,
            "fits_hbm": bool(adjusted <= hbm),
            "hbm_used_frac": adjusted / hbm,
            "hbm_used_frac_raw_cpu": per_dev / hbm,
        }
    except Exception as e:  # record failures for triage — these are bugs
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_tag,
            "skipped": False, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
            "compile_s": round(time.time() - t0, 1),
        }
        print(f"[FAIL] {arch} × {shape_name} × {mesh_tag}: {e}")
        out_path.write_text(json.dumps(rec, indent=1))
        return rec
    out_path.write_text(json.dumps(rec, indent=1))
    print(
        f"[ok] {arch} × {shape_name} × {mesh_tag}  "
        f"compile={rec['compile_s']}s  flops/dev={rec['cost_corrected']['flops']:.3g}  "
        f"hbm={rec['hbm_used_frac']*100:.1f}%  "
        f"coll={rec['collectives_corrected']['total']/2**20:.1f}MiB"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="nothing")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, out_dir, args.remat)
                n_fail += 1 if "error" in rec else 0
    print(f"dry-run complete; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
