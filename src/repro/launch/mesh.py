"""Production meshes.

A function (not a module-level constant) so importing never touches jax
device state.  Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (used by benchmarks/roofline.py).
"""
from __future__ import annotations

import jax

HW = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,  # bytes/s
    "ici_bw": 50e9,  # bytes/s per link
    "hbm_bytes": 16 * 2**30,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the actual local devices (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))
