"""Compiled-artifact analysis: collective bytes, roofline terms.

The three-term roofline (per device):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = Σ collective result bytes / ICI link bw

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are parsed from
the post-SPMD HLO text (``compiled.as_text()``) by summing the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (cross-pod ops are attributed to the pod axis by their
replica-group span when available).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# result shape(s) then op name: `%x = bf16[1,2]{1,0} all-gather(...)` or
# tuple results `%x = (f32[2]{0}, f32[2]{0}) all-reduce(...)`
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_UPCAST_RE = re.compile(
    r"%(wrapped_convert[\w.]*) = f32\[([\d,]+)\]\S*\s*fusion\(%(?:param|arg|p)[\w.]*\)"
)


def cpu_bf16_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> int:
    """CPU-backend artifact: XLA CPU has no native bf16 compute, so it
    materializes f32 converts of bf16 *parameters* (e.g. a full f32 copy of a
    decode KV cache).  TPU reads bf16 natively — these buffers don't exist on
    the target hardware, so the memory report subtracts them (both raw and
    adjusted numbers are recorded).  Only top-level ``wrapped_convert``
    fusions are counted (one per allocation); the inner `convert` ops of
    their bodies and in-loop copies alias the same buffer."""
    total = 0
    seen = set()
    for m in _UPCAST_RE.finditer(hlo_text):
        name, dims = m.groups()
        if name in seen:
            continue
        seen.add(name)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * 4
        if b >= min_bytes:
            total += b  # the f32 copy simply would not exist on TPU
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective op kind."""
    out: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    for m in _LINE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        out[op] = out.get(op, 0) + _shape_bytes(shape_str)
    out["total"] = sum(out[k] for k in _COLL_OPS)
    return out


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    hw: Dict[str, float],
) -> Dict[str, float]:
    compute_s = flops_per_device / hw["peak_flops"]
    memory_s = bytes_per_device / hw["hbm_bw"]
    collective_s = collective_bytes_per_device / hw["ici_bw"]
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    terms.update(
        dominant=dominant,
        step_time_lower_bound_s=bound,
        roofline_fraction=compute_s / bound if bound > 0 else 0.0,
    )
    return terms


def analyze_compiled(compiled, n_devices: int) -> Dict:
    """Extract per-device memory / cost / collective stats."""
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per program
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    colls = parse_collective_bytes(text)
    return {
        "memory": {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "cpu_bf16_upcast_bytes": cpu_bf16_upcast_bytes(text),
        },
        "cost": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        },
        "collectives": colls,
        "n_devices": n_devices,
    }
