"""Launchers: production meshes, multi-pod dry-run, training, hillclimb."""
from repro.launch.mesh import HW, make_host_mesh, make_production_mesh

__all__ = ["HW", "make_host_mesh", "make_production_mesh"]
