import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver: lower one cell with config/sharding overrides and
report the three roofline terms (§Perf methodology: hypothesis → change →
re-lower → re-analyse).

    PYTHONPATH=src python -m repro.launch.hillclimb --arch gemma3-12b \
        --shape train_4k --set remat=dots --set train_microbatches=2
"""
import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.launch import dryrun as DR
from repro.launch.analysis import analyze_compiled, roofline_terms
from repro.launch.mesh import HW


def measure(arch: str, shape: str, overrides=None, remat: str = "nothing",
            multi_pod: bool = False, label: str = "baseline"):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    n_layers = cfg.n_layers

    compiled, n_dev, _ = DR.lower_cell(arch, shape, multi_pod, remat, cfg=cfg)
    stats = analyze_compiled(compiled, n_dev)
    mem = stats["memory"]
    per_dev = (mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
               - mem["alias_bytes"] - mem["cpu_bf16_upcast_bytes"])
    del compiled

    corr_cost, corr_coll = DR.loop_corrected_stats(
        arch, shape, multi_pod, remat, n_layers,
        variant=lambda c, nl: dataclasses.replace(
            DR.analysis_variant(c, nl), **(overrides or {}),
            n_layers=nl, scan_unroll=1 << 30,
        ),
    )
    bytes_cost, _ = DR.loop_corrected_stats(
        arch, shape, multi_pod, remat, n_layers,
        variant=lambda c, nl: dataclasses.replace(
            DR.bytes_variant(c, nl), **(overrides or {}),
            n_layers=nl, scan_unroll=1 << 30,
        ),
    )
    terms = roofline_terms(
        corr_cost["flops"], bytes_cost["bytes_accessed"],
        corr_coll["total"], HW,
    )
    rec = {
        "label": label,
        "arch": arch,
        "shape": shape,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "remat": remat,
        **{k: (round(v, 5) if isinstance(v, float) else v) for k, v in terms.items()},
        "collectives_by_op": {k: int(v) for k, v in corr_coll.items()},
        "hbm_frac": per_dev / HW["hbm_bytes"],
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (int/float parsed)")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--label", default="iteration")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    rec = measure(args.arch, args.shape, overrides, args.remat,
                  args.multi_pod, args.label)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
