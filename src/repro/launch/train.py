"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --scale reduced --steps 100 --batch 8 --seq 128

``--scale full`` uses the exact assigned config (pod-scale); ``reduced``
shrinks to the smoke config for CPU runs.  On a real pod this binary runs
per host under the cluster scheduler; here it exercises the full loop —
data pipeline, sharded step, async checkpointing, restart — on local
devices.
"""
from __future__ import annotations

import argparse


from repro.configs import get_config, reduced
from repro.data import Prefetcher, SyntheticTokens
from repro.models.inputs import synth_train_batch
from repro.train.loop import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--scale", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_run")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--log", default="experiments/train_log.jsonl")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = reduced(cfg)

    if cfg.family in ("vlm", "audio"):
        def it():
            s = 0
            while True:
                yield synth_train_batch(cfg, args.batch, args.seq, seed=s)
                s += 1
        data = it()
    else:
        data = iter(Prefetcher(iter(SyntheticTokens(
            cfg.vocab_size, args.seq, args.batch
        ))))

    trainer = Trainer(
        cfg,
        LoopConfig(
            steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
            lr=args.lr,
        ),
        data,
        tp=args.tp,
    )
    result = trainer.run()
    trainer.save_log(args.log)
    losses = [m["loss"] for m in result["log"] if "loss" in m]
    print(
        f"done: {result['final_step']} steps, "
        f"loss {losses[0]:.3f} → {losses[-1]:.3f}, "
        f"recoveries={result['recoveries']}"
    )


if __name__ == "__main__":
    main()
