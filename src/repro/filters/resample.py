"""Resampling filter (paper pipeline P7: "Resampling XS image over PAN").

Separable interpolation (nearest / bilinear / bicubic) with rational scale
factors.  Output-info transforms size+spacing; requested regions enlarge by
the interpolation support — the canonical example of the paper's
requested-region propagation.

Tap indices and weights are computed host-side in float64 (regions are
static), so coordinate precision holds for 500k-row rasters and XLA folds the
weights into constants.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion

_SUPPORT = {"nearest": 0, "bilinear": 1, "bicubic": 2}


def _cubic_weights(t: np.ndarray) -> np.ndarray:
    """Keys cubic (a=-0.5) weights for fractional offsets t ∈ [0,1).
    Returns (n, 4) for taps at offsets [-1, 0, 1, 2]."""
    a = -0.5
    x = np.stack([t + 1.0, t, 1.0 - t, 2.0 - t], axis=-1)
    ax = np.abs(x)
    w1 = (a + 2.0) * ax**3 - (a + 3.0) * ax**2 + 1.0
    w2 = a * ax**3 - 5.0 * a * ax**2 + 8.0 * a * ax - 4.0 * a
    return np.where(ax <= 1.0, w1, np.where(ax < 2.0, w2, 0.0))


def axis_taps(n_out: int, scale: float, src_offset: float, n_in: int, method: str):
    """Host-side tap plan: (idx (n_out, T) int32, w (n_out, T) float32)."""
    pos = (np.arange(n_out, dtype=np.float64) + 0.5) / scale - 0.5 - src_offset
    if method == "nearest":
        idx = np.clip(np.round(pos).astype(np.int64), 0, n_in - 1)
        return idx.astype(np.int32)[:, None], np.ones((n_out, 1), np.float32)
    base = np.floor(pos).astype(np.int64)
    t = pos - base
    if method == "bilinear":
        taps = np.array([0, 1])
        w = np.stack([1.0 - t, t], axis=-1)
    elif method == "bicubic":
        taps = np.array([-1, 0, 1, 2])
        w = _cubic_weights(t)
    else:
        raise ValueError(method)
    idx = np.clip(base[:, None] + taps[None, :], 0, n_in - 1)
    return idx.astype(np.int32), w.astype(np.float32)


def apply_taps(x: jnp.ndarray, axis: int, idx: np.ndarray, w: np.ndarray) -> jnp.ndarray:
    """y[..., i, ...] = Σ_k w[i,k] · x[..., idx[i,k], ...] along ``axis``."""
    out = None
    for k in range(idx.shape[1]):
        g = jnp.take(x, jnp.asarray(idx[:, k]), axis=axis)
        wk = jnp.asarray(w[:, k]).reshape([-1 if d == axis else 1 for d in range(x.ndim)])
        out = g * wk if out is None else out + g * wk
    return out


class Resample(Filter):
    """Scale an image by rational factors (rows, cols)."""

    cost_per_pixel = 8.0

    def __init__(self, factor_rows, factor_cols=None, method: str = "bicubic", name=None):
        super().__init__(name)
        if factor_cols is None:
            factor_cols = factor_rows
        self.fr = Fraction(factor_rows).limit_denominator(4096)
        self.fc = Fraction(factor_cols).limit_denominator(4096)
        if self.fr <= 0 or self.fc <= 0:
            raise ValueError("factors must be positive")
        self.method = method
        self.support = _SUPPORT[method]

    def output_info(self, info: ImageInfo) -> ImageInfo:
        rows = int(info.rows * self.fr)
        cols = int(info.cols * self.fc)
        return ImageInfo(
            rows, cols, info.bands, np.float32,
            info.geo.scaled(float(self.fr), float(self.fc)), info.nodata,
        )

    def _in_range(self, o0: int, o1: int, f: Fraction) -> Tuple[int, int]:
        """Source index range needed for output index range [o0, o1)."""
        s = self.support
        lo = np.floor((o0 + 0.5) / float(f) - 0.5) - s
        hi = np.ceil((o1 - 0.5) / float(f) - 0.5) + s
        return int(lo), int(hi) + 1

    def requested_region(self, out_region: ImageRegion, info: ImageInfo):
        r0, r1 = self._in_range(out_region.row0, out_region.row1, self.fr)
        c0, c1 = self._in_range(out_region.col0, out_region.col1, self.fc)
        return (ImageRegion((r0, c0), (r1 - r0, c1 - c0)),)

    def plan_key(self, out_region: ImageRegion):
        # generate()'s tap geometry depends on the output origin's *phase* on
        # the resampling lattice, which repeats every ``numerator`` indices —
        # regions sharing this phase (and shape) share one compiled trace
        return (
            out_region.row0 % self.fr.numerator,
            out_region.col0 % self.fc.numerator,
        )

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        x = x.astype(jnp.float32)
        req = self.requested_region(out_region, None)[0]
        # local source coord of local out i: (i+0.5)/f - 0.5 - (req.r0 - out.r0/f)
        off_r = req.row0 - out_region.row0 / float(self.fr)
        off_c = req.col0 - out_region.col0 / float(self.fc)
        ir, wr = axis_taps(out_region.rows, float(self.fr), off_r, x.shape[0], self.method)
        ic, wc = axis_taps(out_region.cols, float(self.fc), off_c, x.shape[1], self.method)
        y = apply_taps(x, 0, ir, wr)
        y = apply_taps(y, 1, ic, wc)
        return y.astype(jnp.float32)
