"""Random-forest image classification (paper pipeline P4).

The paper classifies with an OTB random-forest model.  We build the full
substrate: a numpy CART/forest *trainer* (gini, feature subsampling,
bootstrap) and a vectorized JAX *inference* path — trees are stored as flat
node arrays and every pixel walks them with ``jnp.take`` level-by-level, so
classification is pure tensor math (no data-dependent control flow).

Pointwise per pixel → zero halo → embarrassingly parallel, which is exactly
why the paper's P4 speedup is near-linear.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion


# ---------------------------------------------------------------------------
# training (host, numpy) — produces flat node arrays
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Tree:
    feature: np.ndarray  # (n_nodes,) int32, -1 for leaves
    threshold: np.ndarray  # (n_nodes,) float32
    left: np.ndarray  # (n_nodes,) int32 child index (self-loop on leaves)
    right: np.ndarray  # (n_nodes,) int32
    leaf_class: np.ndarray  # (n_nodes,) int32 (valid everywhere; argmax class)


@dataclasses.dataclass
class Forest:
    trees: List[Tree]
    n_classes: int
    max_depth: int

    def stacked(self) -> Tuple[np.ndarray, ...]:
        """Pad trees to the same node count and stack: (T, n_nodes) arrays."""
        n = max(t.feature.size for t in self.trees)

        def pad(a, fill):
            return np.stack(
                [np.pad(x, (0, n - x.size), constant_values=fill) for x in a]
            )

        return (
            pad([t.feature for t in self.trees], -1).astype(np.int32),
            pad([t.threshold for t in self.trees], 0.0).astype(np.float32),
            pad([t.left for t in self.trees], 0).astype(np.int32),
            pad([t.right for t in self.trees], 0).astype(np.int32),
            pad([t.leaf_class for t in self.trees], 0).astype(np.int32),
        )


def _gini_best_split(X, y, n_classes, feat_ids, rng):
    best = (None, None, np.inf)  # (feat, thr, impurity)
    n = y.size
    for f in feat_ids:
        order = np.argsort(X[:, f], kind="stable")
        xs, ys = X[order, f], y[order]
        counts_left = np.zeros(n_classes)
        counts_right = np.bincount(ys, minlength=n_classes).astype(np.float64)
        for i in range(n - 1):
            counts_left[ys[i]] += 1
            counts_right[ys[i]] -= 1
            if xs[i + 1] <= xs[i]:
                continue
            nl, nr = i + 1.0, n - i - 1.0
            gl = 1.0 - ((counts_left / nl) ** 2).sum()
            gr = 1.0 - ((counts_right / nr) ** 2).sum()
            imp = (nl * gl + nr * gr) / n
            if imp < best[2]:
                best = (f, 0.5 * (xs[i] + xs[i + 1]), imp)
    return best


def _build_tree(X, y, n_classes, max_depth, rng, max_features):
    feature, threshold, left, right, leaf = [], [], [], [], []

    def new_node():
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        leaf.append(0)
        return len(feature) - 1

    def grow(idx, depth):
        node = new_node()
        counts = np.bincount(y[idx], minlength=n_classes)
        leaf[node] = int(counts.argmax())
        if depth >= max_depth or idx.size < 4 or counts.max() == idx.size:
            left[node] = right[node] = node
            return node
        feats = rng.choice(X.shape[1], size=min(max_features, X.shape[1]), replace=False)
        f, thr, _ = _gini_best_split(X[idx], y[idx], n_classes, feats, rng)
        if f is None:
            left[node] = right[node] = node
            return node
        mask = X[idx, f] <= thr
        if mask.all() or not mask.any():
            left[node] = right[node] = node
            return node
        feature[node] = int(f)
        threshold[node] = float(thr)
        left[node] = grow(idx[mask], depth + 1)
        right[node] = grow(idx[~mask], depth + 1)
        return node

    grow(np.arange(y.size), 0)
    return Tree(
        np.array(feature, np.int32),
        np.array(threshold, np.float32),
        np.array(left, np.int32),
        np.array(right, np.int32),
        np.array(leaf, np.int32),
    )


def train_forest(
    X: np.ndarray,
    y: np.ndarray,
    n_trees: int = 8,
    max_depth: int = 8,
    seed: int = 0,
) -> Forest:
    """Bootstrap-aggregated CART forest on (N, F) features / (N,) int labels."""
    rng = np.random.default_rng(seed)
    n_classes = int(y.max()) + 1
    max_features = max(1, int(np.sqrt(X.shape[1])))
    trees = []
    for _ in range(n_trees):
        boot = rng.integers(0, y.size, size=y.size)
        trees.append(
            _build_tree(X[boot], y[boot], n_classes, max_depth, rng, max_features)
        )
    return Forest(trees, n_classes, max_depth)


# ---------------------------------------------------------------------------
# inference (JAX) — level-synchronous tree walk
# ---------------------------------------------------------------------------
def forest_predict(forest_arrays, n_classes: int, max_depth: int, X: jnp.ndarray):
    """X: (N, F) → (N,) predicted class.  forest_arrays = Forest.stacked()."""
    feat, thr, left, right, leaf = [jnp.asarray(a) for a in forest_arrays]
    T = feat.shape[0]

    def walk_tree(t, votes):
        node = jnp.zeros(X.shape[0], jnp.int32)
        for _ in range(max_depth + 1):
            f = jnp.take(feat[t], node)
            th = jnp.take(thr[t], node)
            xval = jnp.take_along_axis(X, jnp.maximum(f, 0)[:, None], axis=1)[:, 0]
            go_left = xval <= th
            nxt = jnp.where(go_left, jnp.take(left[t], node), jnp.take(right[t], node))
            node = jnp.where(f < 0, node, nxt)
        cls = jnp.take(leaf[t], node)
        return votes.at[jnp.arange(X.shape[0]), cls].add(1.0)

    votes = jnp.zeros((X.shape[0], n_classes), jnp.float32)
    for t in range(T):
        votes = walk_tree(t, votes)
    return jnp.argmax(votes, axis=-1).astype(jnp.int32)


class RandomForestClassify(Filter):
    """Per-pixel classification from band values (+ optional normalization)."""

    cost_per_pixel = 16.0

    def __init__(
        self,
        forest: Forest,
        mean: Optional[np.ndarray] = None,
        std: Optional[np.ndarray] = None,
        name=None,
    ):
        super().__init__(name)
        self.forest = forest
        self.arrays = forest.stacked()
        self.mean = None if mean is None else np.asarray(mean, np.float32)
        self.std = None if std is None else np.asarray(std, np.float32)

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, 1, np.int32, info.geo)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        H, W, B = x.shape
        feats = x.reshape(-1, B).astype(jnp.float32)
        if self.mean is not None:
            feats = (feats - self.mean) / jnp.maximum(self.std, 1e-6)
        cls = forest_predict(
            self.arrays, self.forest.n_classes, self.forest.max_depth, feats
        )
        return cls.reshape(H, W, 1)
