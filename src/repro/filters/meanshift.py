"""Mean-shift filtering (paper pipeline P5).

Mode-search smoothing as in OTB's MeanShiftSmoothing: each pixel's range
value v is iterated toward the weighted mean of its fixed spatial window,
weighted by a flat range kernel of bandwidth ``hr``:

    v ← Σ_w  x_w · 1[|x_w − v|² ≤ hr²]  /  Σ_w 1[...]

(``n_iter`` fixed iterations; flat kernels are OTB's default).  The spatial
window stays centered on the source pixel, so the halo is exactly ``hs`` and
the filter is region-independent — the paper's streamability condition.
The paper's Table 2 shows P5 with the *largest* run-time variance (±137 s at
N=1): its cost depends on image content, which is what motivated their
dynamic-load-balancing future work; our LPT scheduler targets exactly this.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion


def meanshift_ref(x: jnp.ndarray, hs: int, hr: float, n_iter: int) -> jnp.ndarray:
    """x: (H + 2hs, W + 2hs, B) pre-padded → (H, W, B)."""
    H = x.shape[0] - 2 * hs
    W = x.shape[1] - 2 * hs
    x = x.astype(jnp.float32)
    # stack the (2hs+1)² spatially shifted windows: (H, W, K, B)
    shifts = []
    for dr in range(-hs, hs + 1):
        for dc in range(-hs, hs + 1):
            shifts.append(x[hs + dr : hs + dr + H, hs + dc : hs + dc + W])
    win = jnp.stack(shifts, axis=2)
    v = x[hs : hs + H, hs : hs + W]
    hr2 = hr * hr
    for _ in range(n_iter):
        d2 = ((win - v[:, :, None, :]) ** 2).sum(axis=-1)  # (H, W, K)
        w = (d2 <= hr2).astype(jnp.float32)[..., None]
        v = (win * w).sum(axis=2) / jnp.maximum(w.sum(axis=2), 1e-12)
    return v


class MeanShift(Filter):
    """``use_pallas`` is tri-state (``kernels.ops.resolve_use_pallas``):
    True forces the Pallas kernel (interpret mode on CPU), False the jnp
    reference, None defers to ``REPRO_USE_PALLAS`` / the backend."""

    cost_per_pixel = 40.0

    def __init__(self, hs: int = 3, hr: float = 100.0, n_iter: int = 4,
                 use_pallas: Optional[bool] = None, name=None):
        super().__init__(name)
        self.hs, self.hr, self.n_iter = hs, hr, n_iter
        self.use_pallas = use_pallas

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, info.bands, np.float32, info.geo)

    def requested_region(self, out_region: ImageRegion, info: ImageInfo):
        return (out_region.pad(self.hs),)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops  # deferred: kernels.ref imports filters

        return ops.meanshift(
            x, self.hs, self.hr, self.n_iter, use_pallas=self.use_pallas
        )

    # -- plan-layer Pallas fast path -----------------------------------------
    def pallas_plan(self) -> bool:
        from repro.kernels import ops

        return ops.resolve_use_pallas(self.use_pallas)

    def pallas_body(self, pre_fns=(None,)):
        from repro.kernels import meanshift as msk

        def body(x):
            return msk.meanshift(
                x, self.hs, self.hr, self.n_iter, pre_fn=pre_fns[0]
            )

        return body
