"""Haralick texture extraction (paper pipeline P2).

Gray-Level Co-occurrence Matrix (GLCM) features over a sliding window:
energy, entropy, contrast, homogeneity, correlation.  The input band is
quantized to ``levels`` gray levels between (vmin, vmax) — static parameters
so the filter stays region-independent (paper §II.C.1).

The reference implementation builds the per-pixel GLCM with one-hot pair
images + cumulative-sum box filters (pure jnp).  The Pallas kernel
(`repro.kernels.glcm`) computes the same thing tile-by-tile in VMEM without
the (H, W, Q²) intermediate.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion

FEATURES = ("energy", "entropy", "contrast", "homogeneity", "correlation")


def quantize(x: jnp.ndarray, vmin: float, vmax: float, levels: int) -> jnp.ndarray:
    q = jnp.floor((x - vmin) / max(1e-12, (vmax - vmin)) * levels)
    return jnp.clip(q, 0, levels - 1).astype(jnp.int32)


def box_sum(x: jnp.ndarray, radius: int) -> jnp.ndarray:
    """Sum over (2r+1)² windows; input must be pre-padded by r on rows/cols."""
    k = 2 * radius + 1
    c = jnp.cumsum(x, axis=0)
    c = jnp.concatenate([c[k - 1 : k], c[k:] - c[:-k]], axis=0)
    c = jnp.cumsum(c, axis=1)
    return jnp.concatenate([c[:, k - 1 : k], c[:, k:] - c[:, :-k]], axis=1)


def glcm_features_ref(
    x: jnp.ndarray,
    radius: int,
    offset: tuple,
    levels: int,
    vmin: float,
    vmax: float,
) -> jnp.ndarray:
    """Oracle: x is (H + 2*halo, W + 2*halo) single band, halo = radius +
    max(|offset|); returns (H, W, 5) features."""
    dr, dc = offset
    m = max(abs(dr), abs(dc))
    q = quantize(x, vmin, vmax, levels)
    H2, W2 = q.shape
    # pair images: q1 at (r, c), q2 at (r+dr, c+dc); valid domain shrinks by m
    q1 = q[m : H2 - m, m : W2 - m]
    q2 = q[m + dr : H2 - m + dr, m + dc : W2 - m + dc]
    oh1 = jnp.eye(levels, dtype=jnp.float32)[q1]
    oh2 = jnp.eye(levels, dtype=jnp.float32)[q2]
    # co-occurrence per pixel = box-sum of the outer product channel images
    pair = oh1[..., :, None] * oh2[..., None, :]  # (h, w, Q, Q)
    hw = pair.shape[:2]
    glcm = box_sum(pair.reshape(hw + (levels * levels,)), radius)  # (H, W, Q²)
    glcm = glcm.reshape(glcm.shape[:2] + (levels, levels))
    return features_from_glcm(glcm)


def features_from_glcm(glcm: jnp.ndarray) -> jnp.ndarray:
    """(..., Q, Q) counts → (..., 5) Haralick features."""
    levels = glcm.shape[-1]
    total = jnp.maximum(glcm.sum(axis=(-2, -1), keepdims=True), 1e-12)
    p = glcm / total
    i = jnp.arange(levels, dtype=jnp.float32)
    ii = i[:, None]
    jj = i[None, :]
    energy = (p * p).sum(axis=(-2, -1))
    entropy = -(p * jnp.log(p + 1e-12)).sum(axis=(-2, -1))
    contrast = (p * (ii - jj) ** 2).sum(axis=(-2, -1))
    homogeneity = (p / (1.0 + (ii - jj) ** 2)).sum(axis=(-2, -1))
    mu_i = (p * ii).sum(axis=(-2, -1))
    mu_j = (p * jj).sum(axis=(-2, -1))
    var_i = (p * (ii - mu_i[..., None, None]) ** 2).sum(axis=(-2, -1))
    var_j = (p * (jj - mu_j[..., None, None]) ** 2).sum(axis=(-2, -1))
    cov = (p * ii * jj).sum(axis=(-2, -1)) - mu_i * mu_j
    # constant windows have var≈0 (up to box-filter rounding): define corr=0
    # there, and keep the denominator well clear of float noise
    denom2 = var_i * var_j
    corr = jnp.where(
        denom2 < 1e-4, 0.0, cov / jnp.sqrt(jnp.maximum(denom2, 1e-4))
    )
    return jnp.stack([energy, entropy, contrast, homogeneity, corr], axis=-1)


class HaralickTextures(Filter):
    """5-band Haralick features from the first band of the input.

    ``use_pallas`` is tri-state (see ``kernels.ops.resolve_use_pallas``):
    True forces the Pallas kernel (interpret mode on CPU), False forces the
    jnp reference, None defers to ``REPRO_USE_PALLAS`` / the backend."""

    cost_per_pixel = 64.0

    def __init__(
        self,
        radius: int = 2,
        offset: tuple = (0, 1),
        levels: int = 8,
        vmin: float = 0.0,
        vmax: float = 4096.0,
        use_pallas: Optional[bool] = None,
        name=None,
    ):
        super().__init__(name)
        self.radius = radius
        self.offset = offset
        self.levels = levels
        self.vmin, self.vmax = vmin, vmax
        self.use_pallas = use_pallas

    @property
    def halo(self) -> int:
        return self.radius + max(abs(self.offset[0]), abs(self.offset[1]))

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, len(FEATURES), np.float32, info.geo)

    def requested_region(self, out_region: ImageRegion, info: ImageInfo):
        return (out_region.pad(self.halo),)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops  # deferred: kernels.ref imports filters

        band = x[..., 0].astype(jnp.float32)
        return ops.glcm_features(
            band, self.radius, self.offset, self.levels, self.vmin, self.vmax,
            use_pallas=self.use_pallas,
        )

    # -- plan-layer Pallas fast path -----------------------------------------
    def pallas_plan(self) -> bool:
        from repro.kernels import ops

        return ops.resolve_use_pallas(self.use_pallas)

    def pallas_body(self, pre_fns=(None,)):
        from repro.kernels import glcm as glcm_kernel

        chain = pre_fns[0]
        if chain is None:
            def pre(t):
                return t[..., 0].astype(jnp.float32)
        else:
            def pre(t):
                return chain(t)[..., 0].astype(jnp.float32)

        def body(x):
            return glcm_kernel.glcm_features(
                x, self.radius, self.offset, self.levels, self.vmin,
                self.vmax, pre_fn=pre,
            )

        return body
