"""Persistent statistics filter (paper §II.C.1's canonical Persistent example).

Accumulates per-band sum / sum² / min / max / count across regions; the
parallel flavor aggregates with psum/pmax/pmin — the paper's MPI
many-to-one pattern in ``Synthesis``.  Mask-aware for SPMD row padding.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.process_object import PersistentFilter, Reduction
from repro.core.region import ImageRegion


class BandStatistics(PersistentFilter):
    supports_mask = True
    state_reductions = {
        "sum": Reduction("sum"),
        "sumsq": Reduction("sum"),
        "count": Reduction("sum"),
        "min": Reduction("min"),
        "max": Reduction("max"),
    }

    def __init__(self, bands: int, name=None):
        super().__init__(name)
        self.bands = bands

    def reset(self):
        b = self.bands
        return {
            "sum": jnp.zeros((b,), jnp.float32),
            "sumsq": jnp.zeros((b,), jnp.float32),
            "count": jnp.zeros((), jnp.float32),
            "min": jnp.full((b,), jnp.inf, jnp.float32),
            "max": jnp.full((b,), -jnp.inf, jnp.float32),
        }

    def accumulate(self, st, region: ImageRegion, x, mask=None):
        x = x.astype(jnp.float32)
        if mask is None:
            mask = jnp.ones((x.shape[0], 1, 1), bool)
        m = jnp.broadcast_to(mask, x.shape)
        xm = jnp.where(m, x, 0.0)
        return {
            "sum": st["sum"] + xm.sum(axis=(0, 1)),
            "sumsq": st["sumsq"] + (xm * xm).sum(axis=(0, 1)),
            "count": st["count"] + m[..., 0].sum(),
            "min": jnp.minimum(st["min"], jnp.where(m, x, jnp.inf).min(axis=(0, 1))),
            "max": jnp.maximum(st["max"], jnp.where(m, x, -jnp.inf).max(axis=(0, 1))),
        }

    def synthesize(self, st):
        mean = st["sum"] / jnp.maximum(st["count"], 1.0)
        var = st["sumsq"] / jnp.maximum(st["count"], 1.0) - mean * mean
        return dict(st, mean=mean, std=jnp.sqrt(jnp.maximum(var, 0.0)))
