"""Orthorectification (paper pipeline P1).

Inverse-mapping warp: for every output (ortho-grid) pixel, an inverse sensor
model gives the source image coordinate, sampled with bicubic interpolation.
The model is affine (rotation/scale/shift — the rigorous part of an RPC fit)
plus a bounded smooth terrain-parallax displacement field, which is the
structure real ortho models expose: a linear trend + bounded local relief.

The requested region is the affine bbox of the output region grown by the
displacement bound + interpolation support — a faithful instance of the
paper's "filters can potentially modify [region] information" (§II.B).

``needs_origin`` — the warp depends on absolute output coordinates, so under
the SPMD strip plan the driver feeds the traced strip origin.  The affine
part cancels origin shifts by construction (requested regions shift with the
same affine pitch), so only the bounded displacement consumes traced
coordinates.

Virtual padded strips cost the warp nothing extra: :meth:`window_bound`
depends only on the output *size*, so the ragged last strip of an uneven
SPMD split — described against the row-padded virtual geometry with the
uniform strip height — gets the same static window as every interior strip,
and :func:`bicubic_sample`'s edge-clamped taps reproduce the streaming
oracle's border replication over any rows the window hangs past the image
(the padded global shard carries edge-replicated values there), keeping
outputs bit-identical across ragged decompositions too.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion


@dataclasses.dataclass(frozen=True)
class SensorModel:
    """Inverse mapping: ortho (row, col) -> source (row, col)."""

    a_rr: float = 1.0
    a_rc: float = 0.0
    a_cr: float = 0.0
    a_cc: float = 1.0
    b_r: float = 0.0
    b_c: float = 0.0
    #: terrain parallax bound (pixels) and wavelengths
    disp_amp: float = 0.0
    disp_wavelength: float = 1000.0

    def affine(self, rr, cc):
        return (
            self.a_rr * rr + self.a_rc * cc + self.b_r,
            self.a_cr * rr + self.a_cc * cc + self.b_c,
        )

    def displacement(self, rr, cc):
        if self.disp_amp == 0.0:
            return 0.0, 0.0
        w = 2.0 * math.pi / self.disp_wavelength
        dr = self.disp_amp * jnp.sin(w * rr) * jnp.cos(0.7 * w * cc)
        dc = self.disp_amp * jnp.cos(0.6 * w * rr) * jnp.sin(w * cc)
        return dr, dc


class Orthorectify(Filter):
    cost_per_pixel = 24.0
    needs_origin = True

    def __init__(self, model: SensorModel, out_rows: int, out_cols: int, name=None):
        super().__init__(name)
        self.model = model
        self.out_rows = out_rows
        self.out_cols = out_cols
        self.support = 2  # bicubic

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(self.out_rows, self.out_cols, info.bands, np.float32, info.geo)

    def requested_region(self, out_region: ImageRegion, info: ImageInfo):
        m = self.model
        corners = [
            m.affine(r, c)
            for r in (out_region.row0, out_region.row1 - 1)
            for c in (out_region.col0, out_region.col1 - 1)
        ]
        margin = m.disp_amp + self.support + 1
        r0 = int(np.floor(min(r for r, _ in corners) - margin))
        r1 = int(np.ceil(max(r for r, _ in corners) + margin)) + 1
        c0 = int(np.floor(min(c for _, c in corners) - margin))
        c1 = int(np.ceil(max(c for _, c in corners) + margin)) + 1
        return (ImageRegion((r0, c0), (r1 - r0, c1 - c0)),)

    def window_bound(self, out_size, info):
        """Static bounding-window shape for any output region of ``out_size``.

        The affine span over the region's corners depends only on the region
        *size*; the fractional origin drift plus the floor/ceil rounding of
        :meth:`requested_region` is bounded by 3 pixels per axis (floor
        loses < 1, ceil gains < 1, plus the +1 exclusive end).  With this
        bound the plan layer folds every same-size ortho request into one
        windowed-read trace instead of one trace per region.
        """
        h, w = out_size
        m = self.model
        margin = m.disp_amp + self.support + 1
        rspan = abs(m.a_rr) * (h - 1) + abs(m.a_rc) * (w - 1)
        cspan = abs(m.a_cr) * (h - 1) + abs(m.a_cc) * (w - 1)
        rows = int(math.ceil(rspan + 2.0 * margin)) + 3
        cols = int(math.ceil(cspan + 2.0 * margin)) + 3
        return ((rows, cols),)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray,
                 origin=None, input_origins=None) -> jnp.ndarray:
        if origin is None:
            origin = out_region.index
        if input_origins is None:
            input_origins = (self.requested_region(out_region, None)[0].index,)
        m = self.model
        H, W = out_region.rows, out_region.cols
        # absolute output coords (row origin may be traced under SPMD);
        # float32 keeps sub-0.1px precision through ~10⁶-row rasters
        rr = jnp.arange(H, dtype=jnp.float32)[:, None] + jnp.asarray(origin[0], jnp.float32)
        cc = jnp.arange(W, dtype=jnp.float32)[None, :] + jnp.asarray(origin[1], jnp.float32)
        ar, ac = m.affine(rr, cc)
        dr, dc = m.displacement(rr, cc)
        # sample at ABSOLUTE coords; the array origin is subtracted in integer
        # index space only, so the interpolation weights are bitwise identical
        # whatever window/request decomposition delivered x (the windowed-read
        # equivalence the cross-executor differential harness asserts)
        return bicubic_sample(x.astype(jnp.float32), ar + dr, ac + dc,
                              origin=input_origins[0])


def bicubic_sample(x: jnp.ndarray, src_r: jnp.ndarray, src_c: jnp.ndarray,
                   origin=(0, 0)) -> jnp.ndarray:
    """Sample (rows, cols, bands) at fractional coords (H, W) → (H, W, bands).

    ``src_r``/``src_c`` are absolute source coordinates; ``origin`` is the
    absolute (row, col) of ``x[0, 0]`` (possibly traced int scalars).  The
    fractional parts come from the absolute coordinates and the origin is
    applied as an exact integer shift of the gather index, so results do not
    depend on which bounding window of the source was materialized; taps
    outside ``x`` edge-clamp (matching ``boundary_pad`` replication when the
    window is flush with the image border).
    """
    n_r, n_c = x.shape[0], x.shape[1]
    fr = jnp.floor(src_r)
    fc = jnp.floor(src_c)
    tr = src_r - fr
    tc = src_c - fc
    br = fr.astype(jnp.int32) - jnp.asarray(origin[0], jnp.int32)
    bc = fc.astype(jnp.int32) - jnp.asarray(origin[1], jnp.int32)
    wr = _cubic_w(tr)  # (H, W, 4)
    wc = _cubic_w(tc)
    flat = x.reshape(-1, x.shape[-1])
    out = jnp.zeros(src_r.shape + (x.shape[-1],), jnp.float32)
    for i in range(4):
        ri = jnp.clip(br + (i - 1), 0, n_r - 1)
        acc_c = jnp.zeros_like(out)
        for j in range(4):
            cj = jnp.clip(bc + (j - 1), 0, n_c - 1)
            g = flat[(ri * n_c + cj).reshape(-1)].reshape(out.shape)
            acc_c = acc_c + wc[..., j][..., None] * g
        out = out + wr[..., i][..., None] * acc_c
    return out


def _cubic_w(t: jnp.ndarray) -> jnp.ndarray:
    a = -0.5
    xx = jnp.stack([t + 1.0, t, 1.0 - t, 2.0 - t], axis=-1)
    ax = jnp.abs(xx)
    w1 = (a + 2.0) * ax**3 - (a + 3.0) * ax**2 + 1.0
    w2 = a * ax**3 - 5.0 * a * ax**2 + 8.0 * a * ax - 4.0 * a
    return jnp.where(ax <= 1.0, w1, jnp.where(ax < 2.0, w2, 0.0))
