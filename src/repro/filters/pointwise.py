"""Pointwise filters: format conversion (paper pipeline P6), band math, NDVI.

Zero-halo, region-independent by construction.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion


class Convert(Filter):
    """Dtype conversion with linear rescale (paper P6: Jpeg2000 → GeoTiff is,
    pixel-wise, a decode + re-encode; the pixel transform is the rescale)."""

    cost_per_pixel = 1.0

    def __init__(self, dtype=np.uint8, in_range=(0.0, 4096.0), out_range=None, name=None):
        super().__init__(name)
        self.dtype = np.dtype(dtype)
        self.in_range = in_range
        if out_range is None:
            if np.issubdtype(self.dtype, np.integer):
                ii = np.iinfo(self.dtype)
                out_range = (float(ii.min), float(ii.max))
            else:
                out_range = (0.0, 1.0)
        self.out_range = out_range

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, info.bands, self.dtype, info.geo)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        (i0, i1), (o0, o1) = self.in_range, self.out_range
        y = (x.astype(jnp.float32) - i0) / (i1 - i0) * (o1 - o0) + o0
        y = jnp.clip(y, min(o0, o1), max(o0, o1))
        return y.astype(self.dtype)

    def pointwise_fn(self):
        # generate() is elementwise and ignores the region — fusable as-is
        return functools.partial(self.generate, None)


class BandMath(Filter):
    """Apply an arbitrary pointwise function of the band vector."""

    def __init__(self, fn: Callable[[jnp.ndarray], jnp.ndarray], out_bands: int,
                 out_dtype=np.float32, name=None):
        super().__init__(name)
        self.fn = fn
        self.out_bands = out_bands
        self.out_dtype = np.dtype(out_dtype)

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, self.out_bands, self.out_dtype, info.geo)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        return self.fn(x.astype(jnp.float32)).astype(self.out_dtype)

    def pointwise_fn(self):
        # generate() is elementwise in the band vector and ignores the
        # region — fusable as-is (ndvi etc. keep row/col shape)
        return functools.partial(self.generate, None)


def ndvi(red_band: int = 0, nir_band: int = 3) -> BandMath:
    def fn(x):
        r, n = x[..., red_band], x[..., nir_band]
        return ((n - r) / jnp.maximum(n + r, 1e-6))[..., None]

    return BandMath(fn, out_bands=1, name="ndvi")


class Composite(Filter):
    """Elementwise reduction across same-grid inputs — the per-pixel
    compositing step of multi-temporal workloads (max-NDVI composites,
    min/mean mosaick­ing).  Zero-halo and region-independent: the reduction
    is per-pixel, so any region decomposition reassembles identically."""

    _OPS = ("max", "min", "mean", "sum")

    def __init__(self, n_inputs: int, op: str = "max", out_dtype=np.float32,
                 name=None):
        if op not in self._OPS:
            raise ValueError(f"op must be one of {self._OPS}, got {op!r}")
        super().__init__(name or f"composite:{op}")
        self.n_inputs = int(n_inputs)
        self.op = op
        self.out_dtype = np.dtype(out_dtype)

    def output_info(self, *infos: ImageInfo) -> ImageInfo:
        rows, cols, bands = infos[0].rows, infos[0].cols, infos[0].bands
        if any((i.rows, i.cols, i.bands) != (rows, cols, bands) for i in infos):
            raise ValueError("Composite inputs must share grid and bands")
        return ImageInfo(rows, cols, bands, self.out_dtype, infos[0].geo)

    def generate(self, out_region: ImageRegion, *xs: jnp.ndarray) -> jnp.ndarray:
        stack = jnp.stack([x.astype(jnp.float32) for x in xs])
        if self.op == "max":
            y = stack.max(axis=0)
        elif self.op == "min":
            y = stack.min(axis=0)
        elif self.op == "mean":
            y = stack.mean(axis=0)
        else:
            y = stack.sum(axis=0)
        return y.astype(self.out_dtype)


class Concat(Filter):
    """Stack the bands of multiple same-grid inputs."""

    def __init__(self, n_inputs: int, name=None):
        super().__init__(name)
        self.n_inputs = n_inputs

    def output_info(self, *infos: ImageInfo) -> ImageInfo:
        rows, cols = infos[0].rows, infos[0].cols
        if any((i.rows, i.cols) != (rows, cols) for i in infos):
            raise ValueError("Concat inputs must share the same grid")
        return ImageInfo(rows, cols, sum(i.bands for i in infos), np.float32, infos[0].geo)

    def generate(self, out_region: ImageRegion, *xs: jnp.ndarray) -> jnp.ndarray:
        return jnp.concatenate([x.astype(jnp.float32) for x in xs], axis=-1)
