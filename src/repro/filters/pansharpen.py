"""Pansharpening (paper pipeline P3): fuse PAN + upsampled XS.

Ratio Component Substitution (the OTB BayesianFusion/RCS default):

    out_b = XS↑_b · PAN / smooth(PAN)

where smooth is a box filter whose support matches the XS→PAN resolution
ratio.  The full P3 graph is ``Resample(XS → PAN grid)`` + this fusion
filter; see ``repro.pipelines.pansharpening``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion
from repro.filters.texture import box_sum


def pansharpen_ref(xs_up: jnp.ndarray, pan: jnp.ndarray, radius: int) -> jnp.ndarray:
    """xs_up: (H, W, B); pan: (H + 2r, W + 2r, 1) pre-padded. → (H, W, B)."""
    k = 2 * radius + 1
    smooth = box_sum(pan.astype(jnp.float32), radius) / (k * k)
    p = pan[radius : pan.shape[0] - radius, radius : pan.shape[1] - radius]
    ratio = p.astype(jnp.float32) / jnp.maximum(smooth, 1e-6)
    return xs_up.astype(jnp.float32) * ratio


class PansharpenFuse(Filter):
    """``use_pallas`` is tri-state (``kernels.ops.resolve_use_pallas``):
    True forces the Pallas kernel (interpret mode on CPU), False the jnp
    reference, None defers to ``REPRO_USE_PALLAS`` / the backend."""

    n_inputs = 2  # (xs_up, pan)
    cost_per_pixel = 6.0

    def __init__(self, radius: int = 2, use_pallas: Optional[bool] = None,
                 name=None):
        super().__init__(name)
        self.radius = radius
        self.use_pallas = use_pallas

    def output_info(self, xs_info: ImageInfo, pan_info: ImageInfo) -> ImageInfo:
        if (xs_info.rows, xs_info.cols) != (pan_info.rows, pan_info.cols):
            raise ValueError("xs_up and pan grids must match")
        return ImageInfo(xs_info.rows, xs_info.cols, xs_info.bands, np.float32, pan_info.geo)

    def requested_region(self, out_region: ImageRegion, xs_info, pan_info):
        return (out_region, out_region.pad(self.radius))

    def generate(self, out_region: ImageRegion, xs_up, pan) -> jnp.ndarray:
        from repro.kernels import ops  # deferred: kernels.ref imports filters

        return ops.pansharpen(xs_up, pan, self.radius, use_pallas=self.use_pallas)

    # -- plan-layer Pallas fast path -----------------------------------------
    def pallas_plan(self) -> bool:
        from repro.kernels import ops

        return ops.resolve_use_pallas(self.use_pallas)

    def pallas_body(self, pre_fns=(None, None)):
        from repro.kernels import pansharpen as psk

        pre_xs, pre_pan = pre_fns

        def body(xs_up, pan):
            return psk.pansharpen(
                xs_up, pan, self.radius, pre_xs=pre_xs, pre_pan=pre_pan
            )

        return body
