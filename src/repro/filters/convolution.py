"""Separable convolution filters (Gaussian smoothing, Sobel gradient).

The paper parallelized "a large number of already implemented [OTB]
pipelines"; smoothing and gradient filters are the canonical
neighborhood-filter family — region-independent with halo = kernel radius.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import Filter, ImageInfo
from repro.core.region import ImageRegion


def _conv_axis(x: jnp.ndarray, k: np.ndarray, axis: int) -> jnp.ndarray:
    """Valid-mode correlation along ``axis`` with a 1-D kernel."""
    r = len(k) // 2
    out = None
    for i, w in enumerate(k):
        sl = [slice(None)] * x.ndim
        n = x.shape[axis] - 2 * r
        sl[axis] = slice(i, i + n)
        term = x[tuple(sl)] * float(w)
        out = term if out is None else out + term
    return out


class SeparableConvolution(Filter):
    """y = k_row ⊗ k_col ⊗ x (per band)."""

    cost_per_pixel = 4.0

    def __init__(self, k_row: Sequence[float], k_col: Optional[Sequence[float]] = None,
                 name=None):
        super().__init__(name)
        self.k_row = np.asarray(k_row, np.float32)
        self.k_col = np.asarray(k_col if k_col is not None else k_row, np.float32)
        if len(self.k_row) % 2 == 0 or len(self.k_col) % 2 == 0:
            raise ValueError("kernels must have odd length")

    @property
    def radius(self):
        return (len(self.k_row) // 2, len(self.k_col) // 2)

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, info.bands, np.float32, info.geo)

    def requested_region(self, out_region: ImageRegion, info: ImageInfo):
        rr, rc = self.radius
        return (out_region.pad(rr, rc),)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        y = _conv_axis(x.astype(jnp.float32), self.k_row, 0)
        return _conv_axis(y, self.k_col, 1)


def gaussian_kernel(sigma: float, radius: Optional[int] = None) -> np.ndarray:
    r = radius if radius is not None else max(1, int(math.ceil(3 * sigma)))
    xs = np.arange(-r, r + 1, dtype=np.float64)
    k = np.exp(-0.5 * (xs / sigma) ** 2)
    return (k / k.sum()).astype(np.float32)


def gaussian_smoothing(sigma: float = 1.5, name=None) -> SeparableConvolution:
    return SeparableConvolution(gaussian_kernel(sigma), name=name or f"gauss{sigma}")


class SobelGradient(Filter):
    """Gradient magnitude from the first band (edge detection)."""

    cost_per_pixel = 6.0

    def output_info(self, info: ImageInfo) -> ImageInfo:
        return ImageInfo(info.rows, info.cols, 1, np.float32, info.geo)

    def requested_region(self, out_region: ImageRegion, info: ImageInfo):
        return (out_region.pad(1),)

    def generate(self, out_region: ImageRegion, x: jnp.ndarray) -> jnp.ndarray:
        b = x[..., :1].astype(jnp.float32)
        smooth = np.array([1.0, 2.0, 1.0], np.float32)
        diff = np.array([-1.0, 0.0, 1.0], np.float32)
        gx = _conv_axis(_conv_axis(b, smooth, 0), diff, 1)
        gy = _conv_axis(_conv_axis(b, diff, 0), smooth, 1)
        return jnp.sqrt(gx * gx + gy * gy)
