"""The paper's pipeline filters P1–P7 + utilities."""
from repro.filters.resample import Resample
from repro.filters.ortho import Orthorectify, SensorModel, bicubic_sample
from repro.filters.texture import HaralickTextures, glcm_features_ref, box_sum
from repro.filters.pansharpen import PansharpenFuse, pansharpen_ref
from repro.filters.meanshift import MeanShift, meanshift_ref
from repro.filters.classify import (
    RandomForestClassify,
    Forest,
    Tree,
    train_forest,
    forest_predict,
)
from repro.filters.pointwise import Convert, BandMath, Composite, Concat, ndvi
from repro.filters.stats import BandStatistics
from repro.filters.convolution import (
    SeparableConvolution,
    SobelGradient,
    gaussian_kernel,
    gaussian_smoothing,
)

__all__ = [
    "Resample",
    "Orthorectify",
    "SensorModel",
    "bicubic_sample",
    "HaralickTextures",
    "glcm_features_ref",
    "box_sum",
    "PansharpenFuse",
    "pansharpen_ref",
    "MeanShift",
    "meanshift_ref",
    "RandomForestClassify",
    "Forest",
    "Tree",
    "train_forest",
    "forest_predict",
    "Convert",
    "BandMath",
    "Composite",
    "Concat",
    "ndvi",
    "BandStatistics",
    "SeparableConvolution",
    "SobelGradient",
    "gaussian_kernel",
    "gaussian_smoothing",
]
