"""Raster file I/O: the RTIF container + strip-parallel writer (paper §II.D).

The paper's writer uses MPI-IO so "multiple MPI processes can write their
piece of data simultaneously in the same unique file", with a row-wise
interleaved pixel layout (faster than tile-wise [16]).

RTIF is a minimal GeoTiff-like container reproducing that layout: a
fixed-size JSON header followed by raw row-major, pixel-interleaved samples.
Because the byte offset of any row range is known in advance, any number of
writers can ``np.memmap`` disjoint strips of the same file concurrently —
the single-host equivalent of MPI-IO file views on a parallel FS.  On a real
pod the same planner drives per-host pwrite()s.
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.process_object import GeoTransform, ImageInfo
from repro.core.region import ImageRegion

MAGIC = b"RTIF0001"
HEADER_BYTES = 4096  # fixed-size header → strip offsets computable a priori


def _header(info: ImageInfo) -> bytes:
    meta = {
        "rows": info.rows,
        "cols": info.cols,
        "bands": info.bands,
        "dtype": np.dtype(info.dtype).str,
        "geo": [
            info.geo.origin_x,
            info.geo.origin_y,
            info.geo.spacing_x,
            info.geo.spacing_y,
        ],
        "nodata": info.nodata,
    }
    payload = MAGIC + json.dumps(meta).encode()
    if len(payload) > HEADER_BYTES:
        raise ValueError("header overflow")
    return payload.ljust(HEADER_BYTES, b"\0")


def read_info(path: str) -> ImageInfo:
    with open(path, "rb") as f:
        head = f.read(HEADER_BYTES)
    if not head.startswith(MAGIC):
        raise ValueError(f"{path}: not an RTIF file")
    meta = json.loads(head[len(MAGIC):].rstrip(b"\0").decode())
    return ImageInfo(
        rows=meta["rows"],
        cols=meta["cols"],
        bands=meta["bands"],
        dtype=np.dtype(meta["dtype"]),
        geo=GeoTransform(*meta["geo"]),
        nodata=meta["nodata"],
    )


def create(path: str, info: ImageInfo) -> None:
    """Pre-size the file (header + full raster) so strip writers can mmap.

    Idempotent for identical metadata: a second writer rank calling begin()
    must not truncate strips already written by its peers (on a cluster,
    rank 0 creates and the others open — here every worker may call it)."""
    total = HEADER_BYTES + info.total_bytes
    head = _header(info)
    if os.path.exists(path) and os.path.getsize(path) == total:
        with open(path, "rb") as f:
            if f.read(HEADER_BYTES) == head:
                return
    with open(path, "wb") as f:
        f.write(head)
        f.truncate(total)


def write_strip(path: str, info: ImageInfo, region: ImageRegion, data: np.ndarray) -> None:
    """Write one strip into its in-file position — concurrency-safe across
    disjoint strips (the MPI-IO analogue)."""
    if region.col0 != 0 or region.cols != info.cols:
        raise ValueError("row-interleaved layout: strips must span full width")
    data = np.ascontiguousarray(data, dtype=info.dtype).reshape(
        region.rows, region.cols, info.bands
    )
    offset = HEADER_BYTES + region.row0 * info.cols * info.bytes_per_pixel
    mm = np.memmap(
        path,
        dtype=info.dtype,
        mode="r+",
        offset=offset,
        shape=(region.rows, region.cols, info.bands),
    )
    mm[:] = data
    mm.flush()
    del mm


class StripWriter:
    """Persistent-descriptor strip writer for the streaming engine's
    write-behind stage.

    ``write_strip`` reopens + remaps the file per strip; this keeps one file
    descriptor and issues ``os.pwrite`` on full-width strips (which are
    contiguous in the row-interleaved layout).  ``pwrite`` ignores the
    descriptor's shared offset, so any number of threads can push disjoint
    regions through one descriptor concurrently — the in-process analogue of
    MPI-IO file views.  Non-full-width regions (tile splits) write one
    ``pwrite`` per row segment, which ``write_strip``'s full-width-only
    contract never supported.

    **Coalescing**: consecutive full-width strips that are row-contiguous
    (exactly what the write-behind stage produces on a stripe split) are
    batched into one ``pwrite`` — RTIF strips are contiguous on disk, so a
    run of fine stripes becomes a single large syscall.  The run is flushed
    when a non-adjacent region arrives, when buffered bytes reach
    ``coalesce_bytes`` (bounding writer memory), on :meth:`flush`, and on
    :meth:`close`; data is only guaranteed on disk after one of those.
    ``coalesce_bytes=0`` disables batching (one syscall per strip, the seed
    behavior).

    **Commit notification**: ``on_commit(row0, row1)`` fires after the bytes
    of full-width rows ``[row0, row1)`` are actually written (post-``pwrite``
    / memmap flush) — *not* when ``write`` merely buffers them into a
    coalescing run.  This is the commit protocol of the region-granularity
    DAG scheduler (:mod:`repro.core.dag`): a downstream stage may read those
    rows the moment the hook fires, and coalescing still works because the
    hook fires once per flushed run, not per buffered strip.  Non-full-width
    (tile) writes never fire the hook — row-granularity commits are only
    meaningful for full-width strips."""

    def __init__(
        self,
        path: str,
        info: ImageInfo,
        coalesce_bytes: int = 8 << 20,
        on_commit: Optional[Callable[[int, int], None]] = None,
    ):
        create(path, info)
        self.path = path
        self.info = info
        self.coalesce_bytes = int(coalesce_bytes)
        self.on_commit = on_commit
        # os.pwrite is POSIX; fall back to a windowed memmap elsewhere so the
        # default raster writer keeps the old write_strip portability
        self._use_pwrite = hasattr(os, "pwrite")
        self._fd: Optional[int] = (
            os.open(path, os.O_RDWR) if self._use_pwrite else -1
        )
        self._lock = threading.Lock()  # guards the pending run
        self._run: List[np.ndarray] = []  # contiguous full-width strips
        self._run_row0 = 0
        self._run_rows = 0
        self._run_bytes = 0

    def _pwrite_all(self, view: memoryview, offset: int) -> None:
        while view:  # pwrite may write short (Linux caps one call near 2 GiB)
            written = os.pwrite(self._fd, view, offset)
            view = view[written:]
            offset += written

    def _memmap_write(self, region: ImageRegion, data: np.ndarray) -> None:
        info = self.info
        mm = np.memmap(
            self.path, dtype=info.dtype, mode="r+", offset=HEADER_BYTES,
            shape=(info.rows, info.cols, info.bands),
        )
        rs, cs = region.slices()
        mm[rs, cs] = data
        mm.flush()
        del mm
        if self.on_commit is not None and region.col0 == 0 and region.cols == info.cols:
            self.on_commit(region.row0, region.row1)

    def _flush_locked(self) -> None:
        if not self._run:
            return
        buf = self._run[0] if len(self._run) == 1 else np.concatenate(self._run)
        row0, rows = self._run_row0, self._run_rows
        offset = HEADER_BYTES + row0 * self.info.cols * self.info.bytes_per_pixel
        self._run = []
        self._run_rows = self._run_bytes = 0
        self._pwrite_all(memoryview(buf).cast("B"), offset)
        if self.on_commit is not None:
            self.on_commit(row0, row0 + rows)  # the whole run is on disk now

    def flush(self) -> None:
        """Force any coalesced pending strips onto disk."""
        with self._lock:
            self._flush_locked()

    def write(self, region: ImageRegion, data: np.ndarray) -> None:
        info = self.info
        if self._fd is None:
            raise ValueError(f"{self.path}: writer already closed")
        caller_buf = data
        data = np.ascontiguousarray(data, dtype=info.dtype).reshape(
            region.rows, region.cols, info.bands
        )
        if not self._use_pwrite:
            self._memmap_write(region, data)
            return
        bpp = info.bytes_per_pixel
        if region.col0 == 0 and region.cols == info.cols:
            with self._lock:
                contiguous = (
                    self._run
                    and region.row0 == self._run_row0 + self._run_rows
                    and self._run_bytes + data.nbytes <= self.coalesce_bytes
                )
                if not contiguous:
                    self._flush_locked()
                    if data.nbytes >= self.coalesce_bytes:
                        # nothing would stay pending: write through directly
                        # (zero-copy — this is also the coalesce_bytes=0 path)
                        self._pwrite_all(
                            memoryview(data).cast("B"),
                            HEADER_BYTES + region.row0 * info.cols * bpp,
                        )
                        if self.on_commit is not None:
                            self.on_commit(region.row0, region.row1)
                        return
                    self._run_row0 = region.row0
                # the run defers the pwrite past this call, so never hold a
                # view of the caller's buffer (ascontiguousarray is a no-copy
                # passthrough when dtype/layout already match) — a caller
                # reusing its buffer must not mutate a pending strip
                if isinstance(caller_buf, np.ndarray) and np.shares_memory(
                    data, caller_buf
                ):
                    data = data.copy()
                self._run.append(data)
                self._run_rows += region.rows
                self._run_bytes += data.nbytes
                if self._run_bytes >= self.coalesce_bytes:
                    self._flush_locked()
            return
        view = memoryview(data).cast("B")
        with self._lock:
            self._flush_locked()  # keep strip/tile write order coherent
        row_bytes = region.cols * bpp
        for i in range(region.rows):
            offset = (
                HEADER_BYTES
                + ((region.row0 + i) * info.cols + region.col0) * bpp
            )
            self._pwrite_all(view[i * row_bytes : (i + 1) * row_bytes], offset)

    def close(self) -> None:
        if self._fd is not None and self._fd >= 0:
            self.flush()
            os.close(self._fd)
        self._fd = None

    def __enter__(self) -> "StripWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _read_region_impl(
    path: str,
    region: Optional[ImageRegion] = None,
    info: Optional[ImageInfo] = None,
) -> np.ndarray:
    """Window read on an RTIF file — the container primitive behind
    :meth:`repro.raster.sources.RasterReader.read_region`."""
    info = info if info is not None else read_info(path)
    region = region or info.full_region
    if region.col0 == 0 and region.cols == info.cols:
        offset = HEADER_BYTES + region.row0 * info.cols * info.bytes_per_pixel
        mm = np.memmap(
            path, dtype=info.dtype, mode="r", offset=offset,
            shape=(region.rows, region.cols, info.bands),
        )
        return np.array(mm)
    # windowed read: row-by-row strided view over the full-width map
    mm = np.memmap(
        path, dtype=info.dtype, mode="r", offset=HEADER_BYTES,
        shape=(info.rows, info.cols, info.bands),
    )
    return np.array(mm[region.row0:region.row1, region.col0:region.col1])


# -- deprecated free-function surface ----------------------------------------
# The read_region / parallel_read / parallel_write trio collapsed into the
# Source/Sink protocol (RasterReader.read_region / .read_many and
# ParallelRasterWriter.write_many).  These wrappers keep seed-era call sites
# working for one release.


def read_region(path: str, region: Optional[ImageRegion] = None) -> np.ndarray:
    """Deprecated: use ``RasterReader(path).read_region(region)``."""
    warnings.warn(
        "repro.raster.io.read_region is deprecated; use "
        "RasterReader(path).read_region(region)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _read_region_impl(path, region)


def parallel_write(
    path: str,
    info: ImageInfo,
    strips: List[Tuple[ImageRegion, np.ndarray]],
    n_writers: int = 1,
) -> None:
    """Deprecated: use ``ParallelRasterWriter(path)`` with ``write_many``
    (thread-level stand-in for the paper's per-process MPI-IO ranks)."""
    warnings.warn(
        "repro.raster.io.parallel_write is deprecated; use "
        "ParallelRasterWriter(path).write_many(strips, n_writers)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.raster.mappers import ParallelRasterWriter

    sink = ParallelRasterWriter(path)
    sink.begin(info)
    try:
        sink.write_many(strips, n_writers=n_writers)
    finally:
        sink.end()


def parallel_read(
    path: str, regions: List[ImageRegion], n_readers: int = 1
) -> List[np.ndarray]:
    """Deprecated: use ``RasterReader(path).read_many(regions, n_readers)``."""
    warnings.warn(
        "repro.raster.io.parallel_read is deprecated; use "
        "RasterReader(path).read_many(regions, n_readers)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.raster.sources import RasterReader

    return RasterReader(path).read_many(regions, n_readers=n_readers)
