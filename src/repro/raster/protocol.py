"""One Source/Sink protocol for raster IO (the cloud-native redesign).

Every raster endpoint — flat RTIF files, in-memory arrays, synthetic scenes,
decimated views, tiled pyramidal containers — speaks the same two mixins:

  * :class:`RasterSource` rides on top of :class:`~repro.core.Source`: a
    uniform ``read_region`` / ``read_many`` / ``info`` / ``overview(level)``
    surface plus a ``capabilities()`` set that tells callers *how* the
    endpoint serves pixels (``tiled`` internal layout, ``pyramidal`` stored
    overview levels, ``range-readable`` byte-range access — the COG triad).
  * :class:`RasterSink` rides on top of :class:`~repro.core.Mapper`:
    ``write_region`` / ``write_many`` mirror the source surface, so the
    executors' ``consume`` protocol and ad-hoc strip writing share one code
    path.

The free-function trio ``io.read_region`` / ``io.parallel_read`` /
``io.parallel_write`` collapses into these methods (thin deprecated wrappers
remain in :mod:`repro.raster.io` for one release).

``overview(level)`` is the zoom contract of the tile-serving engine: level
``L`` is the ``2**L``-decimated view where overview pixel ``(r, c)`` equals
full-resolution pixel ``(r * 2**L, c * 2**L)``.  The default synthesizes it
with :class:`~repro.raster.sources.DecimatedSource` (tile-window reads on the
base, never the full image); ``pyramidal`` sources override it to serve
*stored* levels instead.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.core.process_object import ImageInfo, Mapper, Source
from repro.core.region import ImageRegion

#: capability flags (a subset of the COG feature triad)
CAP_TILED = "tiled"  # pixels live in fixed-size internal tiles
CAP_PYRAMIDAL = "pyramidal"  # stored overview levels (not synthesized)
CAP_RANGE_READABLE = "range-readable"  # windows read as byte ranges


class RasterSource:
    """Protocol mixin for raster sources (mixed into :class:`Source` types).

    Host-side callers use ``read_region`` (numpy out); the execution engine
    keeps calling ``generate`` (jax out) — both resolve through the same
    region math, so a source implements pixels exactly once.
    """

    def capabilities(self) -> frozenset:
        """Which of {tiled, pyramidal, range-readable} this endpoint serves."""
        return frozenset()

    def info(self) -> ImageInfo:
        return self.output_info()

    def read_region(self, region: Optional[ImageRegion] = None) -> np.ndarray:
        """Read one in-image window (whole image when ``region`` is None)."""
        if region is None:
            region = self.output_info().full_region
        return np.asarray(self.generate(region))

    def read_many(
        self, regions: Iterable[ImageRegion], n_readers: int = 1
    ) -> List[np.ndarray]:
        """Read many windows, optionally with concurrent reader threads
        (the protocol successor of ``io.parallel_read``)."""
        regions = list(regions)
        if n_readers <= 1:
            return [self.read_region(r) for r in regions]
        with ThreadPoolExecutor(max_workers=n_readers) as pool:
            return list(pool.map(self.read_region, regions))

    def overview(self, level: int) -> Source:
        """The ``2**level``-decimated zoom view (level 0 is this source)."""
        if level <= 0:
            return self
        from repro.raster.sources import DecimatedSource

        return DecimatedSource(self, 2 ** int(level))

    def read_ahead(self, regions: Iterable[ImageRegion]) -> int:
        """Hint: these windows will be read soon.  Returns how many fetches
        were scheduled (0 for sources with nothing to prefetch — the default).
        The streaming engine hands its region schedule here before the region
        loop so range-readable sources overlap fetches with compute."""
        return 0


class RasterSink:
    """Protocol mixin for raster sinks (mixed into :class:`Mapper` types)."""

    def capabilities(self) -> frozenset:
        return frozenset()

    def write_region(self, region: ImageRegion, data: np.ndarray) -> None:
        """Write one region (alias of the Mapper ``consume`` protocol)."""
        self.consume(region, data)

    def write_many(
        self,
        strips: Iterable[Tuple[ImageRegion, np.ndarray]],
        n_writers: int = 1,
    ) -> None:
        """Write many regions, optionally with concurrent writer threads
        (the protocol successor of ``io.parallel_write``).  Concurrency is
        only used when the sink declares ``thread_safe``."""
        strips = list(strips)
        if n_writers <= 1 or not getattr(self, "thread_safe", False):
            for region, data in strips:
                self.write_region(region, data)
            return
        with ThreadPoolExecutor(max_workers=n_writers) as pool:
            futs = [
                pool.submit(self.write_region, region, data)
                for region, data in strips
            ]
            for f in futs:
                f.result()


def as_source(obj) -> Source:
    """Coerce ``obj`` to a protocol source.

    Sources pass through; a path opens the right reader by container magic
    (RTIF → :class:`~repro.raster.sources.RasterReader`, RTIC →
    :class:`~repro.raster.tiled.TiledSource`); an ndarray wraps in an
    :class:`~repro.raster.sources.ArraySource`.
    """
    if isinstance(obj, Source):
        return obj
    if isinstance(obj, (str, os.PathLike)):
        from repro.raster import io as rio
        from repro.raster.sources import RasterReader
        from repro.raster.tiled import TILED_MAGIC, TiledSource

        path = os.fspath(obj)
        with open(path, "rb") as f:
            magic = f.read(len(rio.MAGIC))
        if magic == TILED_MAGIC:
            return TiledSource(path)
        return RasterReader(path)
    if isinstance(obj, np.ndarray):
        from repro.raster.sources import ArraySource

        return ArraySource(obj)
    raise TypeError(f"cannot make a RasterSource from {type(obj).__name__}")


def as_sink(obj) -> Mapper:
    """Coerce ``obj`` to a protocol sink.

    Mappers pass through; a path opens the matching writer by extension
    (``.rtic`` → :class:`~repro.raster.tiled.TileWriter`, anything else →
    :class:`~repro.raster.mappers.ParallelRasterWriter`).
    """
    if isinstance(obj, Mapper):
        return obj
    if isinstance(obj, (str, os.PathLike)):
        path = os.fspath(obj)
        if path.endswith(".rtic"):
            from repro.raster.tiled import TileWriter

            return TileWriter(path)
        from repro.raster.mappers import ParallelRasterWriter

        return ParallelRasterWriter(path)
    raise TypeError(f"cannot make a RasterSink from {type(obj).__name__}")
