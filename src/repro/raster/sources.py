"""Raster sources: file reader, in-memory arrays, synthetic Spot6-like scenes.

All sources are *region independent* (paper §II.C.1): pixels are a pure
function of absolute pixel coordinates, so any requested-region decomposition
reassembles the identical image.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import GeoTransform, ImageInfo, Source
from repro.core.region import ImageRegion
from repro.raster import io as rio
from repro.raster.protocol import CAP_RANGE_READABLE, RasterSource


class RasterReader(Source, RasterSource):
    """Reads requested windows from an RTIF file (paper: image file reader)."""

    def __init__(self, path: str, name: Optional[str] = None):
        super().__init__(name or f"read:{path}")
        self.path = path
        self._info = rio.read_info(path)

    def capabilities(self) -> frozenset:
        # flat RTIF: any window is a (set of) byte range(s) of the file
        return frozenset({CAP_RANGE_READABLE})

    def output_info(self) -> ImageInfo:
        return self._info

    def read_region(self, region: Optional[ImageRegion] = None) -> np.ndarray:
        return rio._read_region_impl(self.path, region, info=self._info)

    def generate(self, out_region: ImageRegion) -> jnp.ndarray:
        return jnp.asarray(self.read_region(out_region))


class ArraySource(Source, RasterSource):
    """Wraps an in-memory array (rows, cols, bands)."""

    def __init__(
        self,
        array: np.ndarray,
        geo: GeoTransform = GeoTransform(),
        nodata: Optional[float] = None,
        name: Optional[str] = None,
    ):
        super().__init__(name)
        if array.ndim == 2:
            array = array[..., None]
        self.array = np.asarray(array)
        self.geo = geo
        self.nodata = nodata

    def output_info(self) -> ImageInfo:
        r, c, b = self.array.shape
        return ImageInfo(r, c, b, self.array.dtype, self.geo, self.nodata)

    def generate(self, out_region: ImageRegion) -> jnp.ndarray:
        rs, cs = out_region.slices()
        return jnp.asarray(self.array[rs, cs])


class SyntheticScene(Source, RasterSource):
    """Deterministic synthetic very-high-resolution scene (Spot6-like).

    Pixels are computed from absolute (row, col) coordinates: smooth terrain
    + field polygons + linear features, per band — rich enough for textures,
    classification and pansharpening experiments, and fully streamable.
    Mirrors the paper's XS (4-band, 16-bit) / PAN (1-band) products.
    """

    needs_origin = True

    def __init__(
        self,
        rows: int,
        cols: int,
        bands: int = 4,
        dtype=np.uint16,
        geo: GeoTransform = GeoTransform(spacing_x=6.0, spacing_y=-6.0),
        seed: int = 0,
        name: Optional[str] = None,
    ):
        super().__init__(name or f"synthetic{bands}b")
        self.rows, self.cols, self.bands = rows, cols, bands
        self.dtype = np.dtype(dtype)
        self.geo = geo
        self.seed = seed

    def output_info(self) -> ImageInfo:
        return ImageInfo(self.rows, self.cols, self.bands, self.dtype, self.geo)

    def _field(self, rr, cc, band):
        """Pure function of absolute coords → reflectance in [0, 4095]."""
        s = float(self.seed + 1)
        terrain = 600.0 * (
            jnp.sin(rr * (0.002 * s)) * jnp.cos(cc * 0.0017)
            + 0.5 * jnp.sin((rr + 2 * cc) * 0.0009)
        )
        # field polygons: quantized lattice with per-cell pseudo-random level
        cell = (jnp.floor(rr / 97.0) * 31.0 + jnp.floor(cc / 143.0) * 17.0 + band * 7.0 + s)
        fields = 900.0 * (jnp.sin(cell * 12.9898) * 0.5 + 0.5)
        # linear features (roads / rivers)
        road = 700.0 * jnp.exp(-(jnp.abs((rr * 0.37 + cc * 0.93) % 811.0 - 405.0) / 3.0))
        tex = 120.0 * jnp.sin(rr * 0.9 + band) * jnp.cos(cc * 1.1 + band * 2.0)
        base = 800.0 + 180.0 * band
        return base + terrain + fields + road + tex

    def generate(self, out_region: ImageRegion, origin=None) -> jnp.ndarray:
        if origin is None:
            origin = out_region.index
        r0, c0 = origin
        rr = (jnp.arange(out_region.rows, dtype=jnp.float32) + r0)[:, None, None]
        cc = (jnp.arange(out_region.cols, dtype=jnp.float32) + c0)[None, :, None]
        bb = jnp.arange(self.bands, dtype=jnp.float32)[None, None, :]
        vals = self._field(rr, cc, bb)
        vals = jnp.clip(vals, 0.0, 4095.0)
        if np.issubdtype(self.dtype, np.integer):
            return vals.astype(self.dtype)
        return vals.astype(self.dtype)


class DecimatedSource(Source, RasterSource):
    """A zoom-level view of another source: every ``factor``-th pixel.

    The tile-serving engine registers one pipeline per zoom; zoom ``z`` reads
    through ``DecimatedSource(base, 2**z)``.  A requested window maps to a
    ``factor``-scaled window on the base source (tile-window reads: only the
    pixels under the tile are generated, never the full-resolution image),
    then strided — a pure function of absolute coordinates whenever the base
    is, so region decomposition still reassembles identically.
    """

    needs_origin = True

    def __init__(self, base: Source, factor: int, name: Optional[str] = None):
        if factor < 1:
            raise ValueError(f"decimation factor must be >= 1, got {factor}")
        super().__init__(name or f"decim{factor}:{base.name}")
        self.base = base
        self.factor = int(factor)
        self.needs_origin = bool(getattr(base, "needs_origin", False))

    def output_info(self) -> ImageInfo:
        info = self.base.output_info()
        geo = info.geo
        scaled = GeoTransform(
            origin_x=geo.origin_x,
            origin_y=geo.origin_y,
            spacing_x=geo.spacing_x * self.factor,
            spacing_y=geo.spacing_y * self.factor,
        )
        return ImageInfo(
            -(-info.rows // self.factor),
            -(-info.cols // self.factor),
            info.bands,
            info.dtype,
            scaled,
            info.nodata,
        )

    def overview(self, level: int) -> Source:
        """Compose factors instead of nesting views: the level-``L`` overview
        of a ``factor``-decimated view decimates the *base* by
        ``factor * 2**L`` (one strided read, and — because ceil-division
        composes — identical pixels to the nested view)."""
        if level <= 0:
            return self
        return DecimatedSource(self.base, self.factor * 2 ** int(level))

    def generate(self, out_region: ImageRegion, origin=None) -> jnp.ndarray:
        f = self.factor
        if origin is None:
            origin = out_region.index
        info = self.base.output_info()
        r0, c0 = out_region.index[0] * f, out_region.index[1] * f
        # clamp the scaled window to the base image (ragged last tiles when
        # rows/cols aren't factor-multiples); the stride below still yields
        # at least out_region.rows/cols samples, trimmed to exact size
        base_region = ImageRegion(
            (r0, c0),
            (min(out_region.rows * f, info.rows - r0),
             min(out_region.cols * f, info.cols - c0)),
        )
        if self.needs_origin:
            base = self.base.generate(
                base_region, origin=(origin[0] * f, origin[1] * f)
            )
        else:
            base = self.base.generate(base_region)
        return base[::f, ::f][: out_region.rows, : out_region.cols]


def make_spot6_pair(rows_xs: int, cols_xs: int, seed: int = 0):
    """XS (4-band) + PAN (1-band at 4× resolution) synthetic product pair,
    mirroring Table 1 of the paper (PAN ≈ 4× XS resolution)."""
    xs = SyntheticScene(rows_xs, cols_xs, bands=4, seed=seed, name="XS")
    pan = SyntheticScene(
        rows_xs * 4,
        cols_xs * 4,
        bands=1,
        seed=seed + 7,
        geo=GeoTransform(spacing_x=1.5, spacing_y=-1.5),
        name="PAN",
    )
    return xs, pan
