"""Mappers: terminate pipelines by writing or collecting pixels (paper §II.B/D)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.process_object import ImageInfo, Mapper
from repro.core.region import ImageRegion
from repro.raster import io as rio


class MemoryMapper(Mapper):
    """Assemble produced regions into one in-memory array (paper: "interfacing
    with some other system")."""

    def __init__(self, name: Optional[str] = None):
        super().__init__(name)
        self.result: Optional[np.ndarray] = None
        self._info: Optional[ImageInfo] = None

    def begin(self, info: ImageInfo) -> None:
        self._info = info
        self.result = np.zeros((info.rows, info.cols, info.bands), dtype=info.dtype)

    def consume(self, out_region: ImageRegion, data: np.ndarray) -> None:
        rs, cs = out_region.slices()
        self.result[rs, cs] = np.asarray(data, dtype=self._info.dtype).reshape(
            out_region.rows, out_region.cols, self._info.bands
        )


class ParallelRasterWriter(Mapper):
    """The paper's parallel GeoTiff writer (§II.D): every worker writes its
    strips directly into their final in-file position (MPI-IO semantics via
    memmap on disjoint byte ranges).  Static load balancing comes from the
    splitting strategy + schedule, as in the paper."""

    def __init__(self, path: str, name: Optional[str] = None):
        super().__init__(name or f"write:{path}")
        self.path = path
        self._info: Optional[ImageInfo] = None

    def begin(self, info: ImageInfo) -> None:
        self._info = info
        rio.create(self.path, info)

    def consume(self, out_region: ImageRegion, data: np.ndarray) -> None:
        rio.write_strip(self.path, self._info, out_region, np.asarray(data))
