"""Mappers: terminate pipelines by writing or collecting pixels (paper §II.B/D)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.process_object import ImageInfo, Mapper
from repro.core.region import ImageRegion
from repro.raster import io as rio
from repro.raster.protocol import CAP_RANGE_READABLE, RasterSink


class MemoryMapper(Mapper, RasterSink):
    """Assemble produced regions into one in-memory array (paper: "interfacing
    with some other system")."""

    thread_safe = True  # concurrent consumes write disjoint slices

    def __init__(self, name: Optional[str] = None):
        super().__init__(name)
        self.result: Optional[np.ndarray] = None
        self._info: Optional[ImageInfo] = None

    def begin(self, info: ImageInfo) -> None:
        self._info = info
        self.result = np.zeros((info.rows, info.cols, info.bands), dtype=info.dtype)

    def consume(self, out_region: ImageRegion, data: np.ndarray) -> None:
        rs, cs = out_region.slices()
        self.result[rs, cs] = np.asarray(data, dtype=self._info.dtype).reshape(
            out_region.rows, out_region.cols, self._info.bands
        )


class ParallelRasterWriter(Mapper, RasterSink):
    """The paper's parallel GeoTiff writer (§II.D): every worker writes its
    strips directly into their final in-file position (MPI-IO semantics via
    pwrite on disjoint byte ranges of one shared descriptor).  Static load
    balancing comes from the splitting strategy + schedule, as in the paper;
    the work-stealing pool and the write-behind stage rely on the same
    disjoint-range safety.

    For pipelined stage DAGs the writer doubles as the producer end of the
    region-granularity edge protocol: :meth:`bind_commit_sink` attaches an
    :class:`~repro.core.dag.EdgeFanout`-style sink whose ``offer`` applies
    flow control before each strip write and whose ``commit`` fires from the
    :class:`~repro.raster.io.StripWriter` post-write hook once the strip's
    bytes are actually on disk (coalescing-aware — see the StripWriter
    docstring for what "committed" means)."""

    thread_safe = True  # pwrite on disjoint ranges, one descriptor

    def capabilities(self) -> frozenset:
        return frozenset({CAP_RANGE_READABLE})

    def __init__(self, path: str, name: Optional[str] = None):
        super().__init__(name or f"write:{path}")
        self.path = path
        self._info: Optional[ImageInfo] = None
        self._writer: Optional[rio.StripWriter] = None
        self._sink = None

    def bind_commit_sink(self, sink) -> None:
        """Attach a commit sink (``opened``/``offer``/``commit``/``set_flush``)
        before the run starts; the orchestrator wires its edge fanouts here."""
        self._sink = sink

    def begin(self, info: ImageInfo) -> None:
        self._info = info
        self._writer = rio.StripWriter(
            self.path, info,
            on_commit=self._sink.commit if self._sink is not None else None,
        )
        if self._sink is not None:
            self._sink.set_flush(self._writer.flush)
            self._sink.opened(info)

    def consume(self, out_region: ImageRegion, data: np.ndarray) -> None:
        if self._sink is not None:
            self._sink.offer(out_region)  # backpressure before the write
        self._writer.write(out_region, np.asarray(data))

    def end(self) -> None:
        if self._writer is not None:
            self._writer.close()
            self._writer = None
