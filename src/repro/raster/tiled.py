"""RTIC: the tiled, pyramidal, range-readable raster container (COG-style).

Cloud-native geospatial serving reads *byte ranges* of one immutable object:
a fixed header, internally tiled pixel data, and stored overview levels, so
any window at any zoom costs a handful of range requests — never a whole-file
download.  RTIC reproduces that layout over the same data model as RTIF:

    bytes [0, 4096)      header: magic + JSON metadata (dims, dtype, geo,
                         tile geometry, level count, footer index location)
    bytes [4096, ...)    tile blobs: raw row-major pixel-interleaved samples,
                         one contiguous blob per (level, ty, tx) tile; edge
                         tiles are stored clipped (ragged right/bottom)
    footer               JSON index: per-level dims + tile → (offset, length)

Overview level ``L`` stores the ``2**L``-decimated image — level pixel
``(r, c)`` equals full-resolution pixel ``(r * 2**L, c * 2**L)``, exactly the
:class:`~repro.raster.sources.DecimatedSource` contract, so serving a zoom
from a stored level or from an on-the-fly decimation is bit-identical.

Access goes through a minimal **range-read abstraction** (``read(offset,
length)``): :class:`FileRangeReader` serves a local file via ``os.pread``;
:class:`MemoryRangeReader` serves an in-memory blob and counts every request
— the test/bench stand-in for a remote object store.  :class:`TiledSource`
assembles windows from cached tiles and prefetches scheduled tiles on a
background thread (``read_ahead`` — the streaming engine hands it the region
schedule, overlapping range fetches with compute).  :class:`TileWriter` is
the matching sink: it buffers consumed regions into tiles, appends each tile
the moment its pixels are fully covered, accumulates the overview pyramid,
and seals header + footer on ``end()`` — ``TileWriter`` output is exactly
what ``TiledSource`` ingests (round-trip property test in
``tests/test_tiled_io.py``).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import GeoTransform, ImageInfo, Mapper, Source
from repro.core.region import ImageRegion, tile_cover, whole
from repro.raster.protocol import (
    CAP_PYRAMIDAL,
    CAP_RANGE_READABLE,
    CAP_TILED,
    RasterSink,
    RasterSource,
)

TILED_MAGIC = b"RTIC0001"
TILED_HEADER_BYTES = 4096

#: default internal tile geometry (COG-ish; small enough for the test scenes)
DEFAULT_TILE = 64


# -- the range-read abstraction ---------------------------------------------


class FileRangeReader:
    """Range reads on a local file (``os.pread`` — positional, thread-safe).

    The 'local object store': every access is an explicit (offset, length)
    request, the access pattern a remote store would see."""

    def __init__(self, path: str):
        self.path = path
        self._fd: Optional[int] = os.open(path, os.O_RDONLY)
        self._lock = threading.Lock()
        self.requests = 0
        self.bytes_read = 0

    def size(self) -> int:
        return os.fstat(self._fd).st_size

    def read(self, offset: int, length: int) -> bytes:
        buf = os.pread(self._fd, length, offset)
        with self._lock:
            self.requests += 1
            self.bytes_read += len(buf)
        return buf

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def stats(self) -> Dict[str, int]:
        return {"requests": self.requests, "bytes_read": self.bytes_read}


class MemoryRangeReader:
    """Range reads over an in-memory blob — the remote-object-store stand-in.

    Serves slices of one immutable ``bytes`` object and counts every request,
    so tests and benches can assert *how many* range requests a window or an
    overview costs without any network in the loop.  ``latency_s`` adds a
    fixed per-request sleep to model round-trip time (read-ahead overlap
    becomes measurable)."""

    def __init__(self, blob: bytes, latency_s: float = 0.0):
        self._blob = blob
        self.latency_s = float(latency_s)
        self._lock = threading.Lock()
        self.requests = 0
        self.bytes_read = 0

    @classmethod
    def from_file(cls, path: str, latency_s: float = 0.0) -> "MemoryRangeReader":
        with open(path, "rb") as f:
            return cls(f.read(), latency_s=latency_s)

    def size(self) -> int:
        return len(self._blob)

    def read(self, offset: int, length: int) -> bytes:
        if self.latency_s > 0.0:
            import time

            time.sleep(self.latency_s)
        buf = self._blob[offset : offset + length]
        with self._lock:
            self.requests += 1
            self.bytes_read += len(buf)
        return buf

    def close(self) -> None:
        pass

    def stats(self) -> Dict[str, int]:
        return {"requests": self.requests, "bytes_read": self.bytes_read}


# -- the shared container (one per open file, shared by overview views) ------


def _level_dims(rows: int, cols: int, level: int) -> Tuple[int, int]:
    f = 1 << level
    return -(-rows // f), -(-cols // f)


class _TiledContainer:
    """Parsed RTIC file + tile LRU cache + background read-ahead thread.

    One container is shared by every :class:`TiledSource` view of the file
    (all overview levels), so cache and prefetcher are per-file, not
    per-view.  Tile fetches are idempotent (the blob is immutable), so the
    cache is a plain lock-guarded LRU: a rare duplicate fetch between the
    prefetch thread and a synchronous read costs one extra range request,
    never wrong pixels."""

    def __init__(self, reader, cache_tiles: int = 256, owns_reader: bool = True):
        self.reader = reader
        self.owns_reader = owns_reader
        head = reader.read(0, TILED_HEADER_BYTES)
        if not head.startswith(TILED_MAGIC):
            raise ValueError("not an RTIC container")
        meta = json.loads(head[len(TILED_MAGIC):].rstrip(b"\0").decode())
        self.rows = int(meta["rows"])
        self.cols = int(meta["cols"])
        self.bands = int(meta["bands"])
        self.dtype = np.dtype(meta["dtype"])
        self.geo = GeoTransform(*meta["geo"])
        self.nodata = meta["nodata"]
        self.tile_rows = int(meta["tile_rows"])
        self.tile_cols = int(meta["tile_cols"])
        index = json.loads(
            reader.read(meta["index_offset"], meta["index_length"]).decode()
        )
        #: per level: {"rows", "cols", "tiles": {"ty,tx": [offset, length]}}
        self.levels: List[dict] = index["levels"]
        self._lock = threading.Lock()
        self._cache: "OrderedDict[Tuple[int, int, int], np.ndarray]" = OrderedDict()
        self._cache_tiles = max(1, int(cache_tiles))
        self.tile_hits = 0
        self.tile_misses = 0
        self.readahead_scheduled = 0
        self._queue: "queue.Queue[Optional[Tuple[int, int, int]]]" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._closed = False

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def level_info(self, level: int) -> ImageInfo:
        lv = self.levels[level]
        f = 1 << level
        geo = GeoTransform(
            self.geo.origin_x,
            self.geo.origin_y,
            self.geo.spacing_x * f,
            self.geo.spacing_y * f,
        )
        return ImageInfo(
            lv["rows"], lv["cols"], self.bands, self.dtype, geo, self.nodata
        )

    def _tile_region(self, level: int, ty: int, tx: int) -> ImageRegion:
        lv = self.levels[level]
        tile = ImageRegion(
            (ty * self.tile_rows, tx * self.tile_cols),
            (self.tile_rows, self.tile_cols),
        )
        return tile.clamp(whole(lv["rows"], lv["cols"]))

    def tile(self, level: int, ty: int, tx: int) -> np.ndarray:
        key = (level, ty, tx)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.tile_hits += 1
                return hit
            self.tile_misses += 1
        offset, length = self.levels[level]["tiles"][f"{ty},{tx}"]
        raw = self.reader.read(offset, length)
        region = self._tile_region(level, ty, tx)
        arr = np.frombuffer(raw, dtype=self.dtype).reshape(
            region.rows, region.cols, self.bands
        )
        with self._lock:
            self._cache[key] = arr
            self._cache.move_to_end(key)
            while len(self._cache) > self._cache_tiles:
                self._cache.popitem(last=False)
        return arr

    def read_region(self, level: int, region: ImageRegion) -> np.ndarray:
        lv = self.levels[level]
        full = whole(lv["rows"], lv["cols"])
        if not full.contains(region):
            raise ValueError(
                f"read_region {region} outside level-{level} image {full}"
            )
        out = np.empty(
            (region.rows, region.cols, self.bands), dtype=self.dtype
        )
        for ty, tx, tile in tile_cover(
            region, self.tile_rows, self.tile_cols, bounds=full
        ):
            ov = tile.intersect(region)
            data = self.tile(level, ty, tx)
            out[ov.relative_to(region).slices()] = data[
                ov.relative_to(tile).slices()
            ]
        return out

    # -- async read-ahead ----------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            key = self._queue.get()
            if key is None:
                return
            try:
                self.tile(*key)
            except Exception:
                # prefetch is best-effort; the synchronous read path raises
                # the real error when (if) the tile is actually needed
                pass

    def schedule(self, keys: Iterable[Tuple[int, int, int]]) -> int:
        """Enqueue tile fetches on the background thread (started lazily)."""
        n = 0
        with self._lock:
            if self._closed:
                return 0
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._worker_loop, daemon=True, name="rtic-readahead"
                )
                self._worker.start()
            fresh = [k for k in keys if k not in self._cache]
            self.readahead_scheduled += len(fresh)
            n = len(fresh)
        for k in fresh:
            self._queue.put(k)
        return n

    def drain(self, timeout: float = 5.0) -> None:
        """Block until the prefetch queue is empty (tests/benches only)."""
        import time

        deadline = time.monotonic() + timeout
        while not self._queue.empty() and time.monotonic() < deadline:
            time.sleep(0.001)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            worker = self._worker
        if worker is not None:
            self._queue.put(None)
            worker.join(timeout=5.0)
        if self.owns_reader:
            self.reader.close()

    def stats(self) -> Dict[str, int]:
        out = {
            "tile_hits": self.tile_hits,
            "tile_misses": self.tile_misses,
            "readahead_scheduled": self.readahead_scheduled,
            "cached_tiles": len(self._cache),
        }
        if hasattr(self.reader, "stats"):
            out.update(self.reader.stats())
        return out


# -- the source --------------------------------------------------------------


class TiledSource(Source, RasterSource):
    """Reads one level of an RTIC container through the range-read backend.

    ``source`` is a file path (opened with :class:`FileRangeReader`) or any
    range reader (``read(offset, length)`` — e.g. :class:`MemoryRangeReader`
    for the remote stand-in).  Pixels are a pure function of absolute
    coordinates (the container is immutable), so the source is
    region-independent and runs on every executor; ``read_record`` stamps the
    tile geometry + level into plan signatures so a re-tiled container never
    aliases a flat source's plan.
    """

    def __init__(
        self,
        source,
        level: int = 0,
        cache_tiles: int = 256,
        name: Optional[str] = None,
    ):
        if isinstance(source, _TiledContainer):
            self._c = source
        elif isinstance(source, (str, os.PathLike)):
            self._c = _TiledContainer(
                FileRangeReader(os.fspath(source)), cache_tiles=cache_tiles
            )
        else:  # a range reader
            self._c = _TiledContainer(
                source, cache_tiles=cache_tiles, owns_reader=False
            )
        if not (0 <= level < self._c.n_levels):
            raise ValueError(
                f"level {level} not stored (container has {self._c.n_levels})"
            )
        self._level = int(level)
        super().__init__(name or f"tiled:L{self._level}")

    def capabilities(self) -> frozenset:
        return frozenset({CAP_TILED, CAP_PYRAMIDAL, CAP_RANGE_READABLE})

    def output_info(self) -> ImageInfo:
        return self._c.level_info(self._level)

    def generate(self, out_region: ImageRegion) -> jnp.ndarray:
        return jnp.asarray(self._c.read_region(self._level, out_region))

    def read_region(self, region: Optional[ImageRegion] = None) -> np.ndarray:
        if region is None:
            region = self.output_info().full_region
        return self._c.read_region(self._level, region)

    def read_record(self):
        return ("tiled", self._c.tile_rows, self._c.tile_cols, self._level)

    def overview(self, level: int) -> Source:
        """Stored pyramid levels; past the deepest stored level, decimate it."""
        if level <= 0:
            return self
        target = self._level + int(level)
        deepest = self._c.n_levels - 1
        if target <= deepest:
            return TiledSource(self._c, level=target)
        base = TiledSource(self._c, level=deepest)
        from repro.raster.sources import DecimatedSource

        return DecimatedSource(base, 2 ** (target - deepest))

    def read_ahead(self, regions: Iterable[ImageRegion]) -> int:
        info = self.output_info()
        full = info.full_region
        keys: List[Tuple[int, int, int]] = []
        seen = set()
        for region in regions:
            for ty, tx, _ in tile_cover(
                region.clamp(full), self._c.tile_rows, self._c.tile_cols,
                bounds=full,
            ):
                key = (self._level, ty, tx)
                if key not in seen:
                    seen.add(key)
                    keys.append(key)
        return self._c.schedule(keys)

    def stats(self) -> Dict[str, int]:
        return self._c.stats()

    def close(self) -> None:
        self._c.close()


# -- the sink ----------------------------------------------------------------


class TileWriter(Mapper, RasterSink):
    """Writes consumed regions into a fresh RTIC container.

    Level-0 pixels are scattered into per-tile buffers; a tile is appended to
    the file the moment its pixels are fully covered (bounding writer memory
    to the tiles a region cover currently straddles — regions need not align
    with the tile grid, any disjoint cover works).  The overview pyramid
    accumulates in memory (geometric series, < 1/3 of the image) and is
    flushed with the footer index on ``end()``.  ``levels`` counts total
    pyramid levels including full resolution; the default adds levels until
    the coarsest fits in one tile (capped at 9).
    """

    thread_safe = True  # consume() is lock-guarded; pwrite appends are serial

    def __init__(
        self,
        path: str,
        tile_rows: int = DEFAULT_TILE,
        tile_cols: Optional[int] = None,
        levels: Optional[int] = None,
        name: Optional[str] = None,
    ):
        super().__init__(name or f"tilewrite:{path}")
        self.path = path
        self.tile_rows = int(tile_rows)
        self.tile_cols = int(tile_cols if tile_cols is not None else tile_rows)
        if self.tile_rows < 1 or self.tile_cols < 1:
            raise ValueError("tile size must be >= 1")
        self._levels_arg = levels
        self._fd: Optional[int] = None

    def capabilities(self) -> frozenset:
        return frozenset({CAP_TILED, CAP_PYRAMIDAL})

    def begin(self, info: ImageInfo) -> None:
        self._info = info
        if self._levels_arg is not None:
            n_levels = max(1, int(self._levels_arg))
        else:
            n_levels = 1
            while (
                n_levels < 9
                and max(_level_dims(info.rows, info.cols, n_levels - 1))
                > max(self.tile_rows, self.tile_cols)
            ):
                n_levels += 1
        self._dims = [
            _level_dims(info.rows, info.cols, lv) for lv in range(n_levels)
        ]
        self._dtype = np.dtype(info.dtype)
        self._fd = os.open(
            self.path, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o644
        )
        os.pwrite(self._fd, b"\0" * TILED_HEADER_BYTES, 0)  # sealed on end()
        self._next_offset = TILED_HEADER_BYTES
        self._lock = threading.Lock()
        #: level-0 pending tiles: (ty, tx) -> [buffer, covered_pixels]
        self._pending: Dict[Tuple[int, int], list] = {}
        self._index: List[Dict[str, List[int]]] = [{} for _ in range(n_levels)]
        self._ov = [
            np.zeros((r, c, info.bands), dtype=self._dtype)
            for r, c in self._dims[1:]
        ]

    def _append(self, level: int, ty: int, tx: int, buf: np.ndarray) -> None:
        raw = np.ascontiguousarray(buf).tobytes()
        offset = self._next_offset
        self._next_offset += len(raw)
        view = memoryview(raw)
        while view:
            written = os.pwrite(self._fd, view, offset)
            view = view[written:]
            offset += written
        self._index[level][f"{ty},{tx}"] = [
            self._next_offset - len(raw), len(raw)
        ]

    def consume(self, out_region: ImageRegion, data: np.ndarray) -> None:
        info = self._info
        data = np.ascontiguousarray(
            np.asarray(data), dtype=self._dtype
        ).reshape(out_region.rows, out_region.cols, info.bands)
        full = info.full_region
        if not full.contains(out_region):
            raise ValueError(f"consume {out_region} outside image {full}")
        with self._lock:
            for ty, tx, tile in tile_cover(
                out_region, self.tile_rows, self.tile_cols, bounds=full
            ):
                ov = tile.intersect(out_region)
                entry = self._pending.get((ty, tx))
                if entry is None:
                    entry = [
                        np.zeros(
                            (tile.rows, tile.cols, info.bands),
                            dtype=self._dtype,
                        ),
                        0,
                    ]
                    self._pending[(ty, tx)] = entry
                entry[0][ov.relative_to(tile).slices()] = data[
                    ov.relative_to(out_region).slices()
                ]
                entry[1] += ov.num_pixels
                if entry[1] >= tile.num_pixels:
                    self._append(0, ty, tx, entry[0])
                    del self._pending[(ty, tx)]
            # overview pyramid: level L keeps full-res pixels at multiples of
            # 2**L (the DecimatedSource sampling grid), scattered as strided
            # views of this region's data
            for lv in range(1, len(self._dims)):
                f = 1 << lv
                r_start = (-out_region.row0) % f
                c_start = (-out_region.col0) % f
                sub = data[r_start::f, c_start::f]
                if sub.size == 0:
                    continue
                r0 = (out_region.row0 + r_start) // f
                c0 = (out_region.col0 + c_start) // f
                self._ov[lv - 1][
                    r0 : r0 + sub.shape[0], c0 : c0 + sub.shape[1]
                ] = sub

    def end(self) -> None:
        if self._fd is None:
            return
        info = self._info
        with self._lock:
            # partially-covered level-0 tiles flush as-is (uncovered pixels
            # stay zero — same semantics as an under-covered MemoryMapper)
            for (ty, tx), (buf, _) in sorted(self._pending.items()):
                self._append(0, ty, tx, buf)
            self._pending.clear()
            for lv in range(1, len(self._dims)):
                lr, lc = self._dims[lv]
                for ty, tx, tile in tile_cover(
                    whole(lr, lc), self.tile_rows, self.tile_cols,
                    bounds=whole(lr, lc),
                ):
                    self._append(lv, ty, tx, self._ov[lv - 1][tile.slices()])
            index_payload = json.dumps(
                {
                    "levels": [
                        {"rows": r, "cols": c, "tiles": self._index[lv]}
                        for lv, (r, c) in enumerate(self._dims)
                    ]
                }
            ).encode()
            index_offset = self._next_offset
            os.pwrite(self._fd, index_payload, index_offset)
            meta = {
                "rows": info.rows,
                "cols": info.cols,
                "bands": info.bands,
                "dtype": self._dtype.str,
                "geo": [
                    info.geo.origin_x,
                    info.geo.origin_y,
                    info.geo.spacing_x,
                    info.geo.spacing_y,
                ],
                "nodata": info.nodata,
                "tile_rows": self.tile_rows,
                "tile_cols": self.tile_cols,
                "levels": len(self._dims),
                "index_offset": index_offset,
                "index_length": len(index_payload),
            }
            head = TILED_MAGIC + json.dumps(meta).encode()
            if len(head) > TILED_HEADER_BYTES:
                raise ValueError("RTIC header overflow")
            os.pwrite(self._fd, head.ljust(TILED_HEADER_BYTES, b"\0"), 0)
            os.close(self._fd)
            self._fd = None
