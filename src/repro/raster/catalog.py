"""Scene catalogs: multi-scene mosaics and time series over one canvas.

Earth-observation workloads rarely process one image: a *catalog* of scenes
(each with its footprint on a common grid, optionally a timestamp) feeds
mosaics and temporal composites.  :class:`SceneCatalog` is that minimal
catalog; :class:`MosaicSource` exposes a catalog as a single protocol source
— later catalog entries win where footprints overlap (the classic
last-on-top mosaic rule), uncovered canvas gets the fill value.

Assembly is a pure function of absolute canvas coordinates (each scene is
read at scene-local coordinates derived from its placement), so a mosaic is
region-independent whenever its scenes are — it streams, pools, SPMDs and
serves like any other source (pipelines P8/P9 in :mod:`repro.pipelines`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.process_object import GeoTransform, ImageInfo, Source
from repro.core.region import ImageRegion, whole
from repro.raster.protocol import RasterSource


@dataclasses.dataclass(frozen=True)
class SceneEntry:
    """One catalog row: a source + its placement on the canvas grid."""

    source: Source
    #: the scene's footprint in canvas pixel coordinates; its size must match
    #: the scene's own dimensions
    placement: ImageRegion
    #: acquisition time (any orderable scalar; composites sort by it)
    time: int = 0

    def __post_init__(self):
        info = self.source.output_info()
        if (info.rows, info.cols) != self.placement.size:
            raise ValueError(
                f"{self.source.name}: scene is {info.rows}x{info.cols} but "
                f"placement {self.placement} is {self.placement.size}"
            )


class SceneCatalog:
    """An ordered list of scenes on one canvas (later entries win overlaps)."""

    def __init__(
        self,
        entries: Sequence[SceneEntry],
        rows: Optional[int] = None,
        cols: Optional[int] = None,
        fill: float = 0.0,
    ):
        if not entries:
            raise ValueError("empty catalog")
        self.entries: List[SceneEntry] = list(entries)
        bbox = self.entries[0].placement
        for e in self.entries[1:]:
            bbox = bbox.union_bbox(e.placement)
        if bbox.row0 < 0 or bbox.col0 < 0:
            raise ValueError(f"scene placements must be >= (0, 0), got {bbox}")
        self.rows = int(rows) if rows is not None else bbox.row1
        self.cols = int(cols) if cols is not None else bbox.col1
        self.fill = fill
        infos = [e.source.output_info() for e in self.entries]
        bands = {i.bands for i in infos}
        dtypes = {np.dtype(i.dtype) for i in infos}
        if len(bands) != 1 or len(dtypes) != 1:
            raise ValueError(
                f"catalog scenes must share bands/dtype, got {bands}/{dtypes}"
            )
        self.bands = bands.pop()
        self.dtype = dtypes.pop()

    def select(self, region: ImageRegion) -> List[SceneEntry]:
        """Catalog-order entries whose footprint intersects ``region``."""
        return [
            e
            for e in self.entries
            if not e.placement.intersect(region).is_empty()
        ]

    def by_time(self) -> List[SceneEntry]:
        """Entries in acquisition order (stable for equal timestamps)."""
        return sorted(self.entries, key=lambda e: e.time)

    @property
    def full_region(self) -> ImageRegion:
        return whole(self.rows, self.cols)


class MosaicSource(Source, RasterSource):
    """A catalog assembled into one canvas-sized source (later scenes win)."""

    def __init__(
        self,
        catalog: SceneCatalog,
        geo: Optional[GeoTransform] = None,
        name: Optional[str] = None,
    ):
        super().__init__(name or f"mosaic:{len(catalog.entries)}scenes")
        self.catalog = catalog
        self.geo = geo or catalog.entries[0].source.output_info().geo

    def output_info(self) -> ImageInfo:
        c = self.catalog
        return ImageInfo(c.rows, c.cols, c.bands, c.dtype, self.geo)

    def generate(self, out_region: ImageRegion) -> jnp.ndarray:
        c = self.catalog
        out = np.full(
            (out_region.rows, out_region.cols, c.bands), c.fill, dtype=c.dtype
        )
        for e in c.select(out_region):
            ov = e.placement.intersect(out_region)
            # the overlap in scene-local coordinates — scene reads stay
            # window-sized (never the whole scene), so mosaics stream
            local = ov.relative_to(e.placement)
            block = np.asarray(e.source.generate(local)).reshape(
                local.rows, local.cols, c.bands
            )
            out[ov.relative_to(out_region).slices()] = block
        return jnp.asarray(out)


def demo_catalog(
    rows: int = 48,
    cols: int = 32,
    n_scenes: int = 4,
    seed: int = 0,
    bands: int = 4,
    dtype=np.float32,
) -> SceneCatalog:
    """Overlapping quadrant scenes covering a ``rows x cols`` canvas — the
    self-contained catalog behind pipeline P8 (every scene is a
    :class:`~repro.raster.sources.SyntheticScene`, overlaps exercise the
    later-wins rule)."""
    from repro.raster.sources import SyntheticScene

    if n_scenes < 1:
        raise ValueError("need at least one scene")
    half_r = max(1, rows // 2 + rows // 8)
    half_c = max(1, cols // 2 + cols // 8)
    anchors = [
        (0, 0),
        (0, cols - half_c),
        (rows - half_r, 0),
        (rows - half_r, cols - half_c),
    ]
    entries = []
    for t in range(min(n_scenes, len(anchors))):
        r0, c0 = anchors[t]
        scene = SyntheticScene(
            half_r, half_c, bands=bands, dtype=dtype, seed=seed + 13 * t,
            name=f"scene{t}",
        )
        entries.append(
            SceneEntry(scene, ImageRegion((r0, c0), (half_r, half_c)), time=t)
        )
    return SceneCatalog(entries, rows=rows, cols=cols)


def demo_time_series(
    rows: int = 48,
    cols: int = 32,
    periods: int = 3,
    seed: int = 0,
    bands: int = 4,
    dtype=np.float32,
) -> SceneCatalog:
    """Full-canvas scenes at ``periods`` acquisition dates — the catalog
    behind pipeline P9 (per-date NDVI, composited across time)."""
    from repro.raster.sources import SyntheticScene

    entries = [
        SceneEntry(
            SyntheticScene(
                rows, cols, bands=bands, dtype=dtype, seed=seed + 31 * t,
                name=f"t{t}",
            ),
            whole(rows, cols),
            time=t,
        )
        for t in range(periods)
    ]
    return SceneCatalog(entries, rows=rows, cols=cols)
