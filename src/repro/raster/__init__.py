"""Raster substrate: data model, file I/O (strip-parallel RTIF), sources, mappers."""
from repro.raster import io
from repro.raster.sources import (
    ArraySource,
    DecimatedSource,
    RasterReader,
    SyntheticScene,
    make_spot6_pair,
)
from repro.raster.mappers import MemoryMapper, ParallelRasterWriter

__all__ = [
    "io",
    "ArraySource",
    "DecimatedSource",
    "RasterReader",
    "SyntheticScene",
    "make_spot6_pair",
    "MemoryMapper",
    "ParallelRasterWriter",
]
