"""Raster substrate: data model, file I/O (strip-parallel RTIF + tiled
pyramidal RTIC), the Source/Sink protocol, sources, sinks, scene catalogs."""
from repro.raster import io
from repro.raster.protocol import (
    CAP_PYRAMIDAL,
    CAP_RANGE_READABLE,
    CAP_TILED,
    RasterSink,
    RasterSource,
    as_sink,
    as_source,
)
from repro.raster.sources import (
    ArraySource,
    DecimatedSource,
    RasterReader,
    SyntheticScene,
    make_spot6_pair,
)
from repro.raster.tiled import (
    FileRangeReader,
    MemoryRangeReader,
    TiledSource,
    TileWriter,
)
from repro.raster.catalog import (
    MosaicSource,
    SceneCatalog,
    SceneEntry,
    demo_catalog,
    demo_time_series,
)
from repro.raster.mappers import MemoryMapper, ParallelRasterWriter

__all__ = [
    "io",
    "CAP_PYRAMIDAL",
    "CAP_RANGE_READABLE",
    "CAP_TILED",
    "RasterSink",
    "RasterSource",
    "as_sink",
    "as_source",
    "ArraySource",
    "DecimatedSource",
    "RasterReader",
    "SyntheticScene",
    "make_spot6_pair",
    "FileRangeReader",
    "MemoryRangeReader",
    "TiledSource",
    "TileWriter",
    "MosaicSource",
    "SceneCatalog",
    "SceneEntry",
    "demo_catalog",
    "demo_time_series",
    "MemoryMapper",
    "ParallelRasterWriter",
]
