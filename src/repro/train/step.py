"""Train-step builders.

``build_train_step``      — one fused fwd/bwd/update step (dry-run target).
``build_grad_accum_train_step`` — microbatch streaming (the paper's C2
streaming applied to the token domain): ``lax.scan`` over microbatches keeps
the activation footprint at 1/k while XLA overlaps each microbatch's
reduce-scatter with the next microbatch's compute.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim import adamw_update


def build_train_step(
    cfg: ModelConfig,
    lr: float = 3e-4,
    remat: str = "nothing",
) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True
        )(params, cfg, batch, remat)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def build_grad_accum_train_step(
    cfg: ModelConfig,
    n_microbatches: int,
    lr: float = 3e-4,
    remat: str = "nothing",
) -> Callable:
    """Gradient accumulation over k microbatches (batch dim splits k-ways)."""

    def train_step(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            assert b % n_microbatches == 0, (b, n_microbatches)
            return x.reshape((n_microbatches, b // n_microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                params, cfg, mb, remat
            )
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads
            )
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        unroll = n_microbatches if cfg.scan_unroll > 1 else 1
        (gsum, lsum), _ = lax.scan(body, (zeros, jnp.zeros(())), micro,
                                   unroll=unroll)
        grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": lsum / n_microbatches, **opt_metrics}

    return train_step
