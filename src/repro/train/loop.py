"""Fault-tolerant training loop: checkpoint/restart, elastic recovery,
straggler detection.

The loop is the LM-side analogue of the streaming executor: the data
pipeline is the source, the jitted train step the filter, the checkpointer
the (strip-parallel) mapper.  Fault tolerance:

  * periodic async checkpoints with atomic commit;
  * any step failure (device loss, injected fault) triggers recovery: the
    latest committed checkpoint is restored onto a mesh rebuilt from the
    surviving devices (``ckpt.elastic``) and training continues;
  * per-step wall times feed a z-score straggler detector — on a real pod
    this gates the "evict slow host + elastic restart" decision; here it
    logs and counts.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.ckpt.elastic import shrink_mesh
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.sharding import ShardingRules, set_batch_axes
from repro.optim import adamw_init
from repro.train.step import build_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "checkpoints/run"
    lr: float = 3e-4
    log_every: int = 10
    straggler_zscore: float = 3.0
    remat: str = "nothing"


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        loop: LoopConfig,
        data_it: Iterator[Dict[str, np.ndarray]],
        devices: Optional[List] = None,
        fault_hook: Optional[Callable[[int], None]] = None,
        tp: int = 1,
    ):
        self.cfg = cfg
        self.loop = loop
        self.data_it = data_it
        self.devices = list(devices if devices is not None else jax.devices())
        self.fault_hook = fault_hook
        self.tp = tp
        self.metrics_log: List[Dict] = []
        self.step_times: List[float] = []
        self.n_recoveries = 0
        self.straggler_events = 0
        self._build(self.devices)

    # -- (re)build mesh + step -------------------------------------------------
    def _build(self, devices: List) -> None:
        self.mesh = shrink_mesh(devices, prefer_model=self.tp)
        self.rules = ShardingRules(self.mesh, self.cfg)
        set_batch_axes(self.rules.dp_axes, self.rules.tp, self.rules.dp_size, mesh=self.mesh)
        params = lm.init_params(self.cfg, jax.random.PRNGKey(0))
        self.pspecs = self.rules.param_specs(params)
        from repro.optim.adamw import AdamWState

        opt = adamw_init(params)
        ospecs = AdamWState(step=self.rules.replicated(), mu=self.pspecs,
                            nu=jax.tree.map(lambda s: s, self.pspecs))
        step_fn = build_train_step(self.cfg, lr=self.loop.lr, remat=self.loop.remat)
        self._jit_step = jax.jit(
            step_fn,
            in_shardings=(self.pspecs, ospecs, None),
            out_shardings=(self.pspecs, ospecs, None),
            donate_argnums=(0, 1),
        )
        self.params = jax.device_put(params, self.pspecs)
        self.opt = jax.device_put(opt, ospecs)
        self.ckpt = AsyncCheckpointer(self.loop.ckpt_dir)

    # -- recovery ---------------------------------------------------------------
    def _recover(self, devices: List) -> int:
        """Rebuild on surviving devices + restore latest checkpoint."""
        self.n_recoveries += 1
        self.ckpt.wait()
        self._build(devices)
        last = latest_step(self.loop.ckpt_dir)
        if last is None:
            return 0
        _, state = restore_checkpoint(
            self.loop.ckpt_dir,
            like={"params": self.params, "opt": self.opt},
            shardings={"params": self.pspecs, "opt": jax.tree.map(lambda _: None, self.opt)},
        )
        self.params = state["params"]
        self.opt = jax.device_put(state["opt"])
        return last

    # -- main loop ----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        start = latest_step(self.loop.ckpt_dir) or 0
        if start:
            _, state = restore_checkpoint(
                self.loop.ckpt_dir, like={"params": self.params, "opt": self.opt}
            )
            self.params, self.opt = state["params"], state["opt"]
        step = start
        while step < self.loop.steps:
            batch = next(self.data_it)
            t0 = time.time()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                with self.mesh:
                    self.params, self.opt, metrics = self._jit_step(
                        self.params, self.opt, batch
                    )
                metrics = {k: float(v) for k, v in metrics.items()
                           if np.ndim(v) == 0}
            except Exception as e:  # device failure / injected fault
                survivors = self.devices  # single-host: all devices survive
                resume_at = self._recover(survivors)
                self.metrics_log.append(
                    {"step": step, "event": "recovery", "error": str(e)[:200],
                     "resumed_from": resume_at}
                )
                step = resume_at
                continue
            dt = time.time() - t0
            self._watch_stragglers(dt, step)
            step += 1
            if step % self.loop.ckpt_every == 0 or step == self.loop.steps:
                self.ckpt.save(step, {"params": self.params, "opt": self.opt})
            if step % self.loop.log_every == 0 or step == self.loop.steps:
                self.metrics_log.append({"step": step, "time_s": dt, **metrics})
        self.ckpt.wait()
        return {
            "final_step": step,
            "recoveries": self.n_recoveries,
            "straggler_events": self.straggler_events,
            "log": self.metrics_log,
        }

    def _watch_stragglers(self, dt: float, step: int) -> None:
        self.step_times.append(dt)
        hist = self.step_times[-50:]
        if len(hist) >= 10:
            mu, sd = float(np.mean(hist[:-1])), float(np.std(hist[:-1]) + 1e-9)
            if (dt - mu) / sd > self.loop.straggler_zscore:
                self.straggler_events += 1
                self.metrics_log.append(
                    {"step": step, "event": "straggler", "time_s": dt,
                     "mean_s": mu}
                )

    def save_log(self, path: str) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text("\n".join(json.dumps(m) for m in self.metrics_log))
