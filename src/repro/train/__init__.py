from repro.train.step import build_train_step, build_grad_accum_train_step

__all__ = ["build_train_step", "build_grad_accum_train_step"]
