"""Pallas TPU kernels for the compute hot-spots + refs + dispatch wrappers.

Kernels (each: <name>.py with pl.pallas_call + BlockSpec; oracle in ref.py;
jit'd dispatch in ops.py):
  glcm            — per-pixel GLCM Haralick features (paper P2)
  pansharpen      — fused RCS pansharpening (paper P3)
  meanshift       — mode-search filtering (paper P5)
  flash_attention — causal online-softmax attention (LM serving/training)
  ssd_scan        — mamba2 SSD intra-chunk block
"""
from repro.kernels import glcm, pansharpen, meanshift, flash_attention, ssd_scan
from repro.kernels import ops, ref, util

__all__ = [
    "glcm",
    "pansharpen",
    "meanshift",
    "flash_attention",
    "ssd_scan",
    "ops",
    "ref",
    "util",
]
