"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Geospatial kernels reuse the filter reference implementations (they ARE the
pipeline semantics); LM kernels get standalone oracles here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# geospatial oracles (canonical definitions live with the filters)
from repro.filters.texture import glcm_features_ref  # noqa: F401
from repro.filters.pansharpen import pansharpen_ref  # noqa: F401
from repro.filters.meanshift import meanshift_ref  # noqa: F401


def attention_ref(q, k, v, causal: bool = True) -> jnp.ndarray:
    """(BH, Sq, D) × (BH, Skv, D) — plain masked softmax attention."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        qp = jnp.arange(Sq)[:, None]
        kp = jnp.arange(Skv)[None, :]
        s = jnp.where(kp <= qp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_ref(x, dt, cum, B, C):
    """Chunk-local SSD (no incoming state): oracle for ssd_intra_chunk.
    x (BHC,L,P), dt/cum (BHC,L), B/C (BHC,L,N)."""
    xf = x.astype(jnp.float32)
    cb = jnp.einsum("cln,cmn->clm", C.astype(jnp.float32), B.astype(jnp.float32))
    decay = jnp.exp(cum[:, :, None] - cum[:, None, :])
    L = x.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(mask[None], cb * decay, 0.0) * dt[:, None, :]
    y = jnp.einsum("clm,cmp->clp", w, xf)
    w_state = jnp.exp(cum[:, -1:] - cum) * dt  # (BHC, L)
    states = jnp.einsum("cln,clp->cnp", B.astype(jnp.float32) * w_state[..., None], xf)
    return y.astype(x.dtype), states
