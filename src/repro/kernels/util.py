"""Shared kernel utilities: host-side tiling with halos, interpret-mode
auto-detection.

TPU Pallas BlockSpecs address non-overlapping blocks; windowed kernels need
overlapping (haloed) tiles.  ``extract_patches`` materializes the overlap
host-side — a (1 + 2·halo/tile)² footprint — so each grid step streams one
self-contained VMEM tile.  This trades a little HBM bandwidth for fully
static, MXU-aligned VMEM tiling, which is the TPU-idiomatic port of the
paper's "splitting strategy chosen from the memory specification" (§II.B/D):
the splitter-level planning reappears one level down the memory hierarchy.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def interpret_default() -> bool:
    """Pallas interpret mode on CPU hosts (the validation path); compiled on TPU."""
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jnp.ndarray, mult_r: int, mult_c: int, mode: str = "edge"):
    """Pad rows/cols (leading two axes) up to multiples; returns (padded, r, c)."""
    r = (-x.shape[0]) % mult_r
    c = (-x.shape[1]) % mult_c
    if r or c:
        x = jnp.pad(x, [(0, r), (0, c)] + [(0, 0)] * (x.ndim - 2), mode=mode)
    return x, r, c


def extract_patches(x: jnp.ndarray, tile: Tuple[int, int], halo: int) -> jnp.ndarray:
    """x: (H + 2·halo, W + 2·halo, ...) pre-padded → patches
    (nt_r, nt_c, tile+2·halo, tile+2·halo, ...); H, W must divide by tile."""
    th, tw = tile
    H = x.shape[0] - 2 * halo
    W = x.shape[1] - 2 * halo
    assert H % th == 0 and W % tw == 0, (x.shape, tile, halo)
    nt_r, nt_c = H // th, W // tw
    rows = [
        jnp.stack(
            [
                lax_slice(x, i * th, j * tw, th + 2 * halo, tw + 2 * halo)
                for j in range(nt_c)
            ],
            axis=0,
        )
        for i in range(nt_r)
    ]
    return jnp.stack(rows, axis=0)


def lax_slice(x, r0, c0, h, w):
    return jax.lax.dynamic_slice(
        x, (r0, c0) + (0,) * (x.ndim - 2), (h, w) + x.shape[2:]
    )


def stitch_patches(p: jnp.ndarray, out_rows: int, out_cols: int) -> jnp.ndarray:
    """(nt_r, nt_c, th, tw, ...) → (rows, cols, ...), cropped."""
    nt_r, nt_c, th, tw = p.shape[:4]
    y = jnp.moveaxis(p, 2, 1).reshape((nt_r * th, nt_c * tw) + p.shape[4:])
    return y[:out_rows, :out_cols]
