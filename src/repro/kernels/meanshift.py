"""Pallas TPU kernel: mean-shift mode-search filtering (paper pipeline P5).

The jnp reference materializes all (2hs+1)² shifted windows — a K× HBM blow-
up (hs=3 → 49×).  The kernel keeps only the running numerator/denominator in
VMEM and re-slices the haloed tile per offset, so HBM traffic is O(1) per
pixel per iteration instead of O(K).  All iterations run on one resident
tile — arithmetic intensity scales with n_iter·K while bytes stay constant,
pushing the op from memory-bound to compute-bound on TPU.

VMEM per tile (T=128, hs=3, B=4): x (134)²·4·4 ≈ 287 KB + 3 tile buffers.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import extract_patches, interpret_default, stitch_patches


def _ms_kernel(x_ref, out_ref, *, hs, hr, n_iter, tile, pre_fn):
    th, tw = tile
    x = x_ref[0]
    if pre_fn is not None:
        # fused upstream pointwise chain, applied on the VMEM tile
        x = pre_fn(x)
    x = x.astype(jnp.float32)  # (th+2hs, tw+2hs, B)
    B = x.shape[-1]
    v = jax.lax.dynamic_slice(x, (hs, hs, 0), (th, tw, B))
    hr2 = hr * hr
    for _ in range(n_iter):
        num = jnp.zeros((th, tw, B), jnp.float32)
        den = jnp.zeros((th, tw, 1), jnp.float32)
        for u in range(2 * hs + 1):
            for w_ in range(2 * hs + 1):
                xw = jax.lax.dynamic_slice(x, (u, w_, 0), (th, tw, B))
                d2 = ((xw - v) ** 2).sum(-1, keepdims=True)
                m = (d2 <= hr2).astype(jnp.float32)
                num = num + xw * m
                den = den + m
        v = num / jnp.maximum(den, 1e-12)
    out_ref[0] = v


@functools.partial(
    jax.jit, static_argnames=("hs", "hr", "n_iter", "tile", "interpret", "pre_fn")
)
def meanshift(
    x: jnp.ndarray,
    hs: int = 3,
    hr: float = 100.0,
    n_iter: int = 4,
    tile: Tuple[int, int] = (128, 128),
    interpret: Optional[bool] = None,
    pre_fn=None,
) -> jnp.ndarray:
    """x: (H + 2hs, W + 2hs, Bin) pre-padded → (H, W, B).

    ``pre_fn`` (static) is the plan layer's fused pointwise chain, applied
    to the raw haloed tiles inside the kernel; B = Bin without it."""
    if interpret is None:
        interpret = interpret_default()
    H, W, Bin = x.shape[0] - 2 * hs, x.shape[1] - 2 * hs, x.shape[2]
    if pre_fn is not None:
        B = jax.eval_shape(
            pre_fn, jax.ShapeDtypeStruct(x.shape, x.dtype)
        ).shape[-1]
    else:
        B = Bin
    th = min(tile[0], max(8, H))
    tw = min(tile[1], max(8, W))
    Hp, Wp = -(-H // th) * th, -(-W // tw) * tw
    xp = jnp.pad(x, [(0, Hp - H), (0, Wp - W), (0, 0)], mode="edge")
    tiles = extract_patches(xp, (th, tw), hs)
    ntr, ntc = tiles.shape[:2]
    tiles = tiles.reshape(ntr * ntc, th + 2 * hs, tw + 2 * hs, Bin)

    kernel = functools.partial(
        _ms_kernel, hs=hs, hr=hr, n_iter=n_iter, tile=(th, tw), pre_fn=pre_fn
    )
    out = pl.pallas_call(
        kernel,
        grid=(ntr * ntc,),
        in_specs=[
            pl.BlockSpec((1, th + 2 * hs, tw + 2 * hs, Bin), lambda i: (i, 0, 0, 0))
        ],
        out_specs=pl.BlockSpec((1, th, tw, B), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ntr * ntc, th, tw, B), jnp.float32),
        interpret=interpret,
        name="meanshift_mode_search",
    )(tiles)
    return stitch_patches(out.reshape(ntr, ntc, th, tw, B), H, W)
