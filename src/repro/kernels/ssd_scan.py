"""Pallas TPU kernel: SSD intra-chunk block (mamba2 hot spot).

One grid cell = one (batch·head, chunk): computes the chunk-local output

    Y[i] = Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · Δt_j · X[j]

and the chunk state contribution  S_c = Σ_j exp(cum_L − cum_j)·Δt_j·B_j⊗X_j
— both are (L×N)@(N×L)-shaped matmuls on the MXU with a decay-weighted
triangular mask, exactly the SSD "duality" form.  The inter-chunk state
recurrence (tiny, O(nc·N·P)) stays in jnp (`models.ssm.ssd_chunked`).

VMEM per cell (L=256, N=128, P=64): X 64 KB, B/C 128 KB, scores 256 KB.
Oracle: ``kernels.ref.ssd_intra_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import interpret_default


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L,)
    cum = cum_ref[0].astype(jnp.float32)  # (L,) inclusive cumulative log-decay
    B = b_ref[0].astype(jnp.float32)  # (L, N)
    C = c_ref[0].astype(jnp.float32)  # (L, N)
    L = x.shape[0]

    cb = C @ B.T  # (L, L) MXU
    decay = jnp.exp(cum[:, None] - cum[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    w = jnp.where(jj <= ii, cb * decay, 0.0) * dt[None, :]
    y_ref[0] = (w @ x).astype(y_ref.dtype)  # (L, P) MXU

    # chunk state: S_c = (B ⊙ exp(cum_L − cum)·Δt)ᵀ @ X   → (N, P)
    w_state = jnp.exp(cum[-1] - cum) * dt  # (L,)
    s_ref[0] = ((B * w_state[:, None]).T @ x).astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(
    x: jnp.ndarray,  # (BHC, L, P)  batch·head·chunk cells
    dt: jnp.ndarray,  # (BHC, L)
    cum: jnp.ndarray,  # (BHC, L)
    B: jnp.ndarray,  # (BHC, L, N)
    C: jnp.ndarray,  # (BHC, L, N)
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y_intra (BHC, L, P), chunk_states (BHC, N, P))."""
    if interpret is None:
        interpret = interpret_default()
    BHC, L, P = x.shape
    N = B.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(BHC,),
        in_specs=[
            pl.BlockSpec((1, L, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (i, 0)),
            pl.BlockSpec((1, L, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, P), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHC, L, P), x.dtype),
            jax.ShapeDtypeStruct((BHC, N, P), jnp.float32),
        ],
        interpret=interpret,
        name="ssd_intra_chunk",
    )(x, dt, cum, B, C)
