"""Pallas TPU kernel: per-pixel GLCM Haralick features (paper pipeline P2).

Hardware adaptation (GPU→TPU): per-window co-occurrence histograms are
scatter workloads on GPU (atomics into shared-memory bins).  TPUs have no
fast scatter, so the histogram is rebuilt as *vectorized one-hot
accumulation*: for each of the (2R+1)² window offsets, the pair code
``q1·Q + q2`` is compared against a static iota over the Q² bins and added
into a VMEM accumulator — pure VPU work with perfectly regular access, no
atomics, no gather.  Features then come from static per-bin weight vectors
(VPU reductions over the bin axis).

Grid: one program per (tile_r, tile_c) output tile; inputs are pre-tiled
with halos host-side (`kernels.util.extract_patches`), so every block is a
self-contained VMEM working set:

    q tile    (T + 2·halo)²·4B     e.g. (128+8)²·4 ≈ 74 KB
    acc       T²·Q²·4B             128²·64·4 = 4 MB  (Q=8)  « 128 MB VMEM
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.util import (
    extract_patches,
    interpret_default,
    stitch_patches,
)


def _glcm_kernel(x_ref, out_ref, *, radius, offset, levels, vmin, vmax,
                 tile, pre_fn):
    th, tw = tile
    dr, dc = offset
    m = max(abs(dr), abs(dc))
    halo = radius + m
    x = x_ref[0]  # raw (th + 2·halo, tw + 2·halo[, B]) tile
    # fused pre-stage: the upstream pointwise chain (and band selection)
    # runs on the VMEM tile, then quantization — all inside the kernel, so
    # neither the chain's intermediates nor the int32 levels ever hit HBM
    band = (pre_fn(x) if pre_fn is not None else x).astype(jnp.float32)
    q = jnp.clip(
        jnp.floor((band - vmin) / max(1e-12, vmax - vmin) * levels),
        0,
        levels - 1,
    ).astype(jnp.int32)

    nbins = levels * levels
    acc = jnp.zeros((th, tw, nbins), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nbins), 2)
    # window loop is static: (2R+1)² one-hot accumulations
    for u in range(-radius, radius + 1):
        for v in range(-radius, radius + 1):
            q1 = jax.lax.dynamic_slice(q, (halo + u, halo + v), (th, tw))
            q2 = jax.lax.dynamic_slice(q, (halo + u + dr, halo + v + dc), (th, tw))
            code = (q1 * levels + q2)[:, :, None]
            acc = acc + (code == iota).astype(jnp.float32)

    # Haralick features from static bin-weight vectors
    i = jnp.arange(levels, dtype=jnp.float32)
    ii = jnp.repeat(i, levels)  # bin → row level
    jj = jnp.tile(i, levels)  # bin → col level
    total = jnp.maximum(acc.sum(-1, keepdims=True), 1e-12)
    p = acc / total
    energy = (p * p).sum(-1)
    entropy = -(p * jnp.log(p + 1e-12)).sum(-1)
    contrast = (p * ((ii - jj) ** 2)).sum(-1)
    homog = (p / (1.0 + (ii - jj) ** 2)).sum(-1)
    mu_i = (p * ii).sum(-1)
    mu_j = (p * jj).sum(-1)
    var_i = (p * ii * ii).sum(-1) - mu_i * mu_i
    var_j = (p * jj * jj).sum(-1) - mu_j * mu_j
    cov = (p * ii * jj).sum(-1) - mu_i * mu_j
    denom2 = var_i * var_j
    corr = jnp.where(denom2 < 1e-4, 0.0, cov / jnp.sqrt(jnp.maximum(denom2, 1e-4)))
    out_ref[0] = jnp.stack([energy, entropy, contrast, homog, corr], axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "radius", "offset", "levels", "vmin", "vmax", "tile", "interpret",
        "pre_fn",
    ),
)
def glcm_features(
    band: jnp.ndarray,
    radius: int = 2,
    offset: Tuple[int, int] = (0, 1),
    levels: int = 8,
    vmin: float = 0.0,
    vmax: float = 4096.0,
    tile: Tuple[int, int] = (128, 128),
    interpret: Optional[bool] = None,
    pre_fn=None,
) -> jnp.ndarray:
    """band: (H + 2·halo, W + 2·halo) float — pre-padded by halo = radius +
    max|offset| (the filter's requested region).  Returns (H, W, 5).

    With ``pre_fn`` (the plan layer's fused pointwise chain, a static
    argument), ``band`` is instead the *raw* upstream array
    (H + 2·halo, W + 2·halo, ...) and ``pre_fn`` maps its haloed tiles to
    the 2-D float band inside the kernel.  Quantization always runs in the
    kernel, so the int32 level image never materializes in HBM."""
    if interpret is None:
        interpret = interpret_default()
    dr, dc = offset
    halo = radius + max(abs(dr), abs(dc))
    H, W = band.shape[0] - 2 * halo, band.shape[1] - 2 * halo
    # tile the padded image; edge-pad ragged tiles (cropped after — edge
    # padding commutes with the kernel's pointwise pre-stage)
    th = min(tile[0], max(8, H))
    tw = min(tile[1], max(8, W))
    Hp = -(-H // th) * th
    Wp = -(-W // tw) * tw
    extra = band.shape[2:]
    xfull = jnp.pad(
        band, [(0, Hp - H), (0, Wp - W)] + [(0, 0)] * len(extra), mode="edge"
    )
    patches = extract_patches(xfull, (th, tw), halo)
    ntr, ntc = patches.shape[:2]
    patches = patches.reshape((ntr * ntc, th + 2 * halo, tw + 2 * halo) + extra)

    kernel = functools.partial(
        _glcm_kernel, radius=radius, offset=offset, levels=levels,
        vmin=vmin, vmax=vmax, tile=(th, tw), pre_fn=pre_fn,
    )
    blk = (1, th + 2 * halo, tw + 2 * halo) + extra
    nd = len(blk)
    out = pl.pallas_call(
        kernel,
        grid=(ntr * ntc,),
        in_specs=[pl.BlockSpec(blk, lambda i, _n=nd: (i,) + (0,) * (_n - 1))],
        out_specs=pl.BlockSpec((1, th, tw, 5), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ntr * ntc, th, tw, 5), jnp.float32),
        interpret=interpret,
        name="glcm_haralick",
    )(patches)
    return stitch_patches(out.reshape(ntr, ntc, th, tw, 5), H, W)
