"""Pallas TPU kernel: causal flash attention (serving/training hot spot).

Online-softmax attention with explicit VMEM blocking: grid over
(batch·heads, q-blocks); the kv stream is walked in ``block_k`` slices of
the VMEM-resident (S, D) ref with running max/denominator in f32.  Block
sizes are MXU-aligned (q=128, k=128 default; D is the lane dim).

This kernel validates the *algorithm* used by the pure-jnp
``models.layers.blockwise_attention`` (the production path XLA partitions
across the mesh); on TPU the kernel replaces the inner per-device
computation.  Oracle: ``kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import interpret_default


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, sq, skv, scale):
    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (bq, D)
    bq, D = q.shape
    nk = skv // block_k

    m = jnp.full((bq,), -jnp.inf, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, D), jnp.float32)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    for j in range(nk):  # static loop → fully pipelined on TPU
        k = k_ref[0, pl.dslice(j * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T  # (bq, bk)
        if causal:
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1
            )
            s = jnp.where(k_pos <= q_pos[:, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[:, None] + p @ v
        m = m_new
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Skv, D)
    v: jnp.ndarray,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    nq = Sq // block_q
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, causal=causal, sq=Sq, skv=Skv,
        scale=1.0 / math.sqrt(D),
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Skv, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Skv, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
