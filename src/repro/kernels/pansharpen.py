"""Pallas TPU kernel: fused RCS pansharpening (paper pipeline P3).

Fuses the PAN box smoothing, the ratio, and the per-band multiply into one
VMEM pass — the unfused jnp path materializes smooth(PAN) and the ratio in
HBM (3 extra full-image round trips).  Box sum uses the running cumsum
formulation along rows/cols inside the tile.

VMEM per tile (T=256, r=2, B=4): pan (T+4)²·4 ≈ 270 KB, xs 256²·4·4 = 1 MB.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import extract_patches, interpret_default, stitch_patches


def _ps_kernel(xs_ref, pan_ref, out_ref, *, radius, tile, pre_xs, pre_pan):
    th, tw = tile
    k = 2 * radius + 1
    # fused pre-stages: upstream pointwise chains run on the VMEM tiles; the
    # PAN band is selected here (after the chain), so the raw multiband tile
    # streams in once and nothing intermediate touches HBM
    pan = pan_ref[0]
    if pre_pan is not None:
        pan = pre_pan(pan)
    pan = pan[..., 0].astype(jnp.float32)  # (th+2r, tw+2r)
    xs = xs_ref[0]
    if pre_xs is not None:
        xs = pre_xs(xs)
    xs = xs.astype(jnp.float32)  # (th, tw, B)
    # box filter via shifted accumulation (static loop, register-friendly)
    acc = jnp.zeros((th, tw), jnp.float32)
    for u in range(k):
        for v in range(k):
            acc = acc + jax.lax.dynamic_slice(pan, (u, v), (th, tw))
    smooth = acc / (k * k)
    center = jax.lax.dynamic_slice(pan, (radius, radius), (th, tw))
    ratio = center / jnp.maximum(smooth, 1e-6)
    out_ref[0] = xs * ratio[:, :, None]


@functools.partial(
    jax.jit, static_argnames=("radius", "tile", "interpret", "pre_xs", "pre_pan")
)
def pansharpen(
    xs_up: jnp.ndarray,
    pan: jnp.ndarray,
    radius: int = 2,
    tile: Tuple[int, int] = (256, 256),
    interpret: Optional[bool] = None,
    pre_xs=None,
    pre_pan=None,
) -> jnp.ndarray:
    """xs_up: (H, W, Bin); pan: (H + 2r, W + 2r, Bp) pre-padded → (H, W, B).

    ``pre_xs`` / ``pre_pan`` are the plan layer's fused pointwise chains
    (static arguments), applied to the raw input tiles inside the kernel;
    the PAN *band selection* also happens in-kernel (after ``pre_pan``), so
    ``pan`` keeps its band axis.  Without chains B = Bin and Bp may be 1."""
    if interpret is None:
        interpret = interpret_default()
    H, W, Bin = xs_up.shape
    if pre_xs is not None:
        B = jax.eval_shape(
            pre_xs, jax.ShapeDtypeStruct(xs_up.shape, xs_up.dtype)
        ).shape[-1]
    else:
        B = Bin
    Bp = pan.shape[-1]
    th = min(tile[0], max(8, H))
    tw = min(tile[1], max(8, W))
    Hp, Wp = -(-H // th) * th, -(-W // tw) * tw
    xs_p = jnp.pad(xs_up, [(0, Hp - H), (0, Wp - W), (0, 0)], mode="edge")
    pan_p = jnp.pad(pan, [(0, Hp - H), (0, Wp - W), (0, 0)], mode="edge")
    xs_tiles = extract_patches(xs_p, (th, tw), 0)
    pan_tiles = extract_patches(pan_p, (th, tw), radius)
    ntr, ntc = xs_tiles.shape[:2]
    xs_tiles = xs_tiles.reshape(ntr * ntc, th, tw, Bin)
    pan_tiles = pan_tiles.reshape(
        ntr * ntc, th + 2 * radius, tw + 2 * radius, Bp
    )

    kernel = functools.partial(
        _ps_kernel, radius=radius, tile=(th, tw), pre_xs=pre_xs, pre_pan=pre_pan
    )
    out = pl.pallas_call(
        kernel,
        grid=(ntr * ntc,),
        in_specs=[
            pl.BlockSpec((1, th, tw, Bin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(
                (1, th + 2 * radius, tw + 2 * radius, Bp),
                lambda i: (i, 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, th, tw, B), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ntr * ntc, th, tw, B), jnp.float32),
        interpret=interpret,
        name="pansharpen_rcs",
    )(xs_tiles, pan_tiles)
    return stitch_patches(out.reshape(ntr, ntc, th, tw, B), H, W)
