"""Jit'd dispatch wrappers: Pallas kernel or pure-jnp oracle, per flag.

Flag resolution (:func:`resolve_use_pallas`), in priority order:

  1. explicit ``use_pallas=True`` / ``False`` always wins.  ``True`` on a
     CPU host deterministically selects Pallas **interpret** mode (every
     kernel defaults ``interpret=None`` → ``interpret_default()``, which is
     true off-TPU) — never a silent jnp fallback, so CI exercises the real
     kernel code path on CPU runners.
  2. ``use_pallas=None`` consults the ``REPRO_USE_PALLAS`` env var
     (``1/true/yes/on`` or ``0/false/no/off``) — one switch flips a whole
     process (all filters, all executors) without threading the flag.
  3. unset env falls back to the backend default: Pallas on TPU, the jnp
     reference elsewhere.

The plan layer's Pallas fast path (``ProcessObject.pallas_plan``) resolves
through the same function, so the fused-kernel decision recorded in a plan
signature and the per-call dispatch below can never disagree.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import glcm as _glcm
from repro.kernels import meanshift as _ms
from repro.kernels import pansharpen as _ps
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def resolve_use_pallas(flag: Optional[bool]) -> bool:
    """Resolve a tri-state ``use_pallas`` flag (see module docstring)."""
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_USE_PALLAS", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    if env:
        raise ValueError(
            f"REPRO_USE_PALLAS={env!r}: expected one of "
            f"{_TRUTHY + _FALSY} (or unset)"
        )
    return jax.default_backend() == "tpu"


# internal alias kept for callers of the original private name
_use_pallas = resolve_use_pallas


def glcm_features(band, radius=2, offset=(0, 1), levels=8, vmin=0.0,
                  vmax=4096.0, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _glcm.glcm_features(band, radius, offset, levels, vmin, vmax, **kw)
    return _ref.glcm_features_ref(band, radius, offset, levels, vmin, vmax)


def pansharpen(xs_up, pan, radius=2, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _ps.pansharpen(xs_up, pan, radius, **kw)
    return _ref.pansharpen_ref(xs_up, pan, radius)


def meanshift(x, hs=3, hr=100.0, n_iter=4, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _ms.meanshift(x, hs, hr, n_iter, **kw)
    return _ref.meanshift_ref(x, hs, hr, n_iter)


def flash_attention(q, k, v, causal=True, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _fa.flash_attention(q, k, v, causal, **kw)
    return _ref.attention_ref(q, k, v, causal)


def ssd_intra_chunk(x, dt, cum, B, C, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _ssd.ssd_intra_chunk(x, dt, cum, B, C, **kw)
    return _ref.ssd_intra_ref(x, dt, cum, B, C)
