"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle otherwise
(or force with ``use_pallas=True`` → interpret mode on CPU)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import glcm as _glcm
from repro.kernels import meanshift as _ms
from repro.kernels import pansharpen as _ps
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref


def _use_pallas(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() == "tpu"


def glcm_features(band, radius=2, offset=(0, 1), levels=8, vmin=0.0,
                  vmax=4096.0, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _glcm.glcm_features(band, radius, offset, levels, vmin, vmax, **kw)
    return _ref.glcm_features_ref(band, radius, offset, levels, vmin, vmax)


def pansharpen(xs_up, pan, radius=2, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _ps.pansharpen(xs_up, pan, radius, **kw)
    return _ref.pansharpen_ref(xs_up, pan, radius)


def meanshift(x, hs=3, hr=100.0, n_iter=4, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _ms.meanshift(x, hs, hr, n_iter, **kw)
    return _ref.meanshift_ref(x, hs, hr, n_iter)


def flash_attention(q, k, v, causal=True, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _fa.flash_attention(q, k, v, causal, **kw)
    return _ref.attention_ref(q, k, v, causal)


def ssd_intra_chunk(x, dt, cum, B, C, use_pallas: Optional[bool] = None, **kw):
    if _use_pallas(use_pallas):
        return _ssd.ssd_intra_chunk(x, dt, cum, B, C, **kw)
    return _ref.ssd_intra_ref(x, dt, cum, B, C)
