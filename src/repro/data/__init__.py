from repro.data.pipeline import BOS, Prefetcher, SyntheticTokens

__all__ = ["BOS", "Prefetcher", "SyntheticTokens"]
