"""Synthetic token data pipeline: deterministic, sharded, prefetched.

The pipeline is the *source* process object of the LM training graph in the
paper's terms: region = a global-batch step, decomposed across hosts (each
host materializes only its slice), streamed with background prefetch
(bounded queue — the paper's bounded-memory streaming).

Documents are Zipf-ish token runs with local n-gram structure (so loss
actually falls during the example runs), packed into fixed-length sequences
with BOS separators; labels are next-token shifted.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

BOS = 1


class SyntheticTokens:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
        order: int = 2,
    ):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // host_count
        self.host_index = host_index
        self.seed = seed
        # deterministic bigram table: each token prefers a few successors
        rng = np.random.default_rng(seed)
        self.n_next = 4
        self.table = rng.integers(
            2, vocab_size, size=(min(vocab_size, 4096), self.n_next)
        ).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 64 + self.host_index
        )
        B, S = self.local_batch, self.seq + 1
        toks = np.empty((B, S), np.int32)
        cur = rng.integers(2, min(self.vocab, 4096), size=B).astype(np.int32)
        toks[:, 0] = BOS
        for t in range(1, S):
            choose = rng.integers(0, self.n_next, size=B)
            nxt = self.table[cur % self.table.shape[0], choose]
            # 10% resets start new "documents"
            reset = rng.random(B) < 0.02
            nxt = np.where(reset, BOS, nxt)
            toks[:, t] = nxt
            cur = np.where(reset, rng.integers(2, min(self.vocab, 4096), size=B), nxt).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with a bounded queue."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def run():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=run, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
