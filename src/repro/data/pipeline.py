"""Synthetic token data pipeline: deterministic, sharded, prefetched.

The pipeline is the *source* process object of the LM training graph in the
paper's terms: region = a global-batch step, decomposed across hosts (each
host materializes only its slice), streamed with background prefetch
(bounded queue — the paper's bounded-memory streaming).

Documents are Zipf-ish token runs with local n-gram structure (so loss
actually falls during the example runs), packed into fixed-length sequences
with BOS separators; labels are next-token shifted.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

BOS = 1


class SyntheticTokens:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
        order: int = 2,
    ):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // host_count
        self.host_index = host_index
        self.seed = seed
        # deterministic bigram table: each token prefers a few successors
        rng = np.random.default_rng(seed)
        self.n_next = 4
        self.table = rng.integers(
            2, vocab_size, size=(min(vocab_size, 4096), self.n_next)
        ).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 64 + self.host_index
        )
        B, S = self.local_batch, self.seq + 1
        toks = np.empty((B, S), np.int32)
        cur = rng.integers(2, min(self.vocab, 4096), size=B).astype(np.int32)
        toks[:, 0] = BOS
        for t in range(1, S):
            choose = rng.integers(0, self.n_next, size=B)
            nxt = self.table[cur % self.table.shape[0], choose]
            # 10% resets start new "documents"
            reset = rng.random(B) < 0.02
            nxt = np.where(reset, BOS, nxt)
            toks[:, t] = nxt
            cur = np.where(reset, rng.integers(2, min(self.vocab, 4096), size=B), nxt).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with a bounded queue.

    Hardened for churn (the tile-serving engine creates and destroys one per
    zoom level): ``close()`` is **idempotent** and exception-safe — it
    signals the producer (which never blocks indefinitely on a full queue),
    joins the thread with a timeout, drains the queue, and leaves a drain
    sentinel so a consumer blocked in ``__next__`` wakes with
    ``StopIteration`` instead of hanging.  An exception raised by the
    wrapped iterator is captured and re-raised on the consumer side; a
    finished iterator raises ``StopIteration`` (the seed behavior blocked
    forever on both).  ``poll()`` is the non-blocking variant the serving
    engine uses to drain completed neighbor prefetches opportunistically.
    """

    _DONE = object()  # drain sentinel: producer finished (or was closed)

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._closed = False

        def put(item) -> bool:
            # bounded-wait put: re-checks the stop flag so close() never has
            # to race a producer blocked on a full queue
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def run():
            try:
                for item in it:
                    if not put(item):
                        return
            except BaseException as e:  # noqa: BLE001 — crosses threads
                self._error = e
            finally:
                put(self._DONE)

        self.t = threading.Thread(target=run, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def _finish(self):
        # re-offer the sentinel so any other blocked consumer wakes too
        try:
            self.q.put_nowait(self._DONE)
        except queue.Full:
            pass
        if self._error is not None:
            raise self._error
        raise StopIteration

    def __next__(self):
        if self._closed:
            raise StopIteration
        item = self.q.get()
        if item is self._DONE:
            self._finish()
        return item

    def poll(self):
        """Non-blocking ``__next__``: the next prefetched item, or ``None``
        when nothing is ready yet.  A captured iterator error re-raises here
        exactly as it would in ``__next__``."""
        if self._closed:
            return None
        try:
            item = self.q.get_nowait()
        except queue.Empty:
            return None
        if item is self._DONE:
            try:
                self._finish()
            except StopIteration:
                return None
        return item

    def close(self, timeout: float = 2.0) -> None:
        """Idempotent, exception-safe teardown.  Signals the producer (its
        bounded-wait put observes the flag within 50 ms even against a full
        queue), joins with ``timeout``, drains buffered items, and parks a
        drain sentinel for late consumers.  Captured iterator errors are
        dropped — close means "no longer interested"."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self.t.join(timeout=timeout)
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        try:
            self.q.put_nowait(self._DONE)
        except queue.Full:  # pragma: no cover — queue was just drained
            pass
