from repro.optim.adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.compress import (
    compress_gradients,
    decompress_gradients,
    init_residuals,
    local_scales,
)

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "compress_gradients",
    "decompress_gradients",
    "init_residuals",
    "local_scales",
]
