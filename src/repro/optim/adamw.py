"""AdamW with decoupled weight decay + global-norm clipping.

Implemented directly (no optax dependency in this environment); optimizer
moments live in float32 and shard exactly like their parameters.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
