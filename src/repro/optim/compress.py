"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Beyond-paper distributed-optimization feature: the pod axis crosses the
slowest links (data-center interconnect between pods), so the DP gradient
all-reduce there dominates at scale.  Per-tensor symmetric int8 quantization
with error feedback (residuals carried to the next step) cuts those bytes 4×
versus f32 / 2× versus bf16, with convergence preserved by the standard
EF-SGD argument.

All ranks must quantize with a *shared* scale so that the int accumulation
commutes with dequantization:

    local_scale = max|g+r| / 127
    scale   = lax.pmax(local_scale, "pod")          # agree across ranks
    q, r'   = quantize(g + r, scale)
    g_sum   = lax.psum(q.astype(int32), "pod")      # 1-byte wire format
    g_mean  = g_sum * scale / n_pods
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def local_scales(grads: Any, residuals: Any) -> Any:
    return jax.tree.map(
        lambda g, r: jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32) + r)), 1e-12)
        / 127.0,
        grads,
        residuals,
    )


def compress_gradients(grads: Any, residuals: Any, scales: Any) -> Tuple[Any, Any]:
    """Quantize (g + residual) with the given shared scales.
    Returns (int8 tensors, new residuals)."""

    def comp(g, r, s):
        gf = g.astype(jnp.float32) + r
        q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
        return q, gf - q.astype(jnp.float32) * s

    out = jax.tree.map(comp, grads, residuals, scales)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    q = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return q, new_r


def decompress_gradients(q_sum: Any, scales: Any, n_ranks: int) -> Any:
    """q_sum: int32 sums over ranks → mean float gradients."""
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * (s / max(1, n_ranks)), q_sum, scales
    )


def init_residuals(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
