"""The paper's seven pipelines: streamed/tiled execution == whole-image
oracle (region independence, §II.C.1) on synthetic Spot6-like scenes."""
import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import StreamingExecutor, StripeSplitter, TileSplitter
from repro.raster import SyntheticScene, make_spot6_pair


def _src(rows=64, cols=48):
    return SyntheticScene(rows, cols, bands=4, dtype=np.float32)


CASES = {
    "P1_ortho": (lambda: PP.p1_orthorectification(_src()), 1e-3),
    "P2_textures": (lambda: PP.p2_textures(_src()), 1e-3),
    "P3_pansharpen": (lambda: PP.p3_pansharpening(*make_spot6_pair(16, 12)), 1e-3),
    "P4_classify": (lambda: PP.p4_classification(_src()), 0.0),
    "P5_meanshift": (
        lambda: PP.p5_meanshift(_src(48, 40), hs=2, n_iter=2), 1e-3),
    "P6_convert": (lambda: PP.p6_conversion(_src()), 1.0),
    "P7_resample": (lambda: PP.p7_resampling(_src(32, 24)), 1e-3),
}


@pytest.mark.parametrize("name", list(CASES))
def test_pipeline_streamed_equals_whole(name):
    build, atol = CASES[name]
    p, m = build()
    info = p.info(m)
    whole = np.asarray(p.pull(m, info.full_region)).astype(np.float64)

    p2, m2 = build()
    StreamingExecutor(p2, m2, StripeSplitter(n_splits=5)).run()
    np.testing.assert_allclose(m2.result.astype(np.float64), whole,
                               rtol=1e-4, atol=atol)

    p3, m3 = build()
    StreamingExecutor(p3, m3, TileSplitter(13, 17)).run()
    np.testing.assert_allclose(m3.result.astype(np.float64), whole,
                               rtol=1e-4, atol=atol)


def test_p4_classifier_learns_labels():
    """The trained forest reproduces the rule-based labels well above chance."""
    from repro.filters import train_forest, forest_predict
    import numpy as np

    rng = np.random.default_rng(0)
    X = rng.normal(size=(1500, 4)).astype(np.float32)
    mix = X @ np.linspace(1.0, 2.0, 4)
    edges = np.quantile(mix, [0.25, 0.5, 0.75])
    y = np.digitize(mix, edges)
    forest = train_forest(X[:1000], y[:1000], n_trees=8, max_depth=8)
    pred = np.asarray(
        forest_predict(forest.stacked(), forest.n_classes, forest.max_depth,
                       X[1000:])
    )
    acc = (pred == y[1000:]).mean()
    assert acc > 0.7, acc  # 4-class chance = 0.25


def test_p2_feature_ranges():
    """Haralick sanity: energy∈(0,1], entropy≥0, |corr|≤1."""
    p, m = PP.p2_textures(_src(40, 32))
    out = np.asarray(p.pull(m, p.info(m).full_region))
    energy, entropy, contrast, homog, corr = np.moveaxis(out, -1, 0)
    assert (energy > 0).all() and (energy <= 1 + 1e-5).all()
    assert (entropy >= -1e-5).all()
    assert (contrast >= -1e-5).all()
    assert (homog > 0).all() and (homog <= 1 + 1e-5).all()
    assert (np.abs(corr) <= 1 + 1e-4).all()
