"""The paper's central invariant (§II.C.1): region-independent pipelines
produce identical pixels under ANY splitting — streamed == whole-image."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    Filter,
    Pipeline,
    StreamingExecutor,
    StripeSplitter,
    TileSplitter,
)
from repro.filters import BandStatistics
from repro.raster import MemoryMapper, SyntheticScene


class BoxMean(Filter):
    def __init__(self, radius):
        super().__init__()
        self.radius = radius

    def requested_region(self, out_region, *infos):
        return (out_region.pad(self.radius),)

    def generate(self, out_region, x):
        r = self.radius
        k = 2 * r + 1
        c = jnp.cumsum(x, axis=0)
        c = jnp.concatenate([c[k - 1 : k], c[k:] - c[:-k]], axis=0)
        c2 = jnp.cumsum(c, axis=1)
        c2 = jnp.concatenate([c2[:, k - 1 : k], c2[:, k:] - c2[:, :-k]], axis=1)
        return c2 / (k * k)


def build(rows, cols, radius, depth):
    p = Pipeline()
    node = p.add(SyntheticScene(rows, cols, bands=2, dtype=np.float32))
    for _ in range(depth):
        node = p.add(BoxMean(radius), [node])
    m = p.add(MemoryMapper(), [node])
    return p, m


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(16, 60),
    cols=st.integers(16, 60),
    radius=st.integers(0, 3),
    depth=st.integers(1, 3),
    n_splits=st.integers(2, 9),
)
def test_streamed_equals_whole_stripes(rows, cols, radius, depth, n_splits):
    p, m = build(rows, cols, radius, depth)
    whole_img = np.asarray(p.pull(m, p.info(m).full_region))
    p2, m2 = build(rows, cols, radius, depth)
    StreamingExecutor(p2, m2, StripeSplitter(n_splits=n_splits)).run()
    np.testing.assert_allclose(m2.result, whole_img, rtol=3e-5, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    rows=st.integers(16, 50),
    cols=st.integers(16, 50),
    radius=st.integers(0, 2),
    th=st.integers(5, 20),
    tw=st.integers(5, 20),
)
def test_streamed_equals_whole_tiles(rows, cols, radius, th, tw):
    p, m = build(rows, cols, radius, 2)
    whole_img = np.asarray(p.pull(m, p.info(m).full_region))
    p2, m2 = build(rows, cols, radius, 2)
    StreamingExecutor(p2, m2, TileSplitter(th, tw)).run()
    np.testing.assert_allclose(m2.result, whole_img, rtol=3e-5, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(n_splits=st.integers(1, 10), rows=st.integers(20, 60))
def test_persistent_stats_split_invariant(n_splits, rows):
    """Persistent aggregation == global statistics, any split count."""
    def mk():
        p = Pipeline()
        s = p.add(SyntheticScene(rows, 30, bands=3, dtype=np.float32))
        st_ = p.add(BandStatistics(bands=3), [s])
        m = p.add(MemoryMapper(), [st_])
        return p, m

    p, m = mk()
    img = np.asarray(p.pull(m, p.info(m).full_region))
    p2, m2 = mk()
    res = StreamingExecutor(p2, m2, StripeSplitter(n_splits=n_splits)).run()
    stats = res.persistent_results["BandStatistics"]
    np.testing.assert_allclose(
        np.asarray(stats["mean"]), img.reshape(-1, 3).mean(0), rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(stats["max"]), img.reshape(-1, 3).max(0), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(stats["std"]), img.reshape(-1, 3).std(0), rtol=1e-3, atol=1e-3
    )


def test_worker_partition_processes_everything():
    """Multi-worker static schedule: the union of worker outputs is the image."""
    rows, cols, W = 40, 30, 3
    acc = np.zeros((rows, cols, 2), np.float32)
    ref_p, ref_m = build(rows, cols, 1, 1)
    whole_img = np.asarray(ref_p.pull(ref_m, ref_p.info(ref_m).full_region))
    for w in range(W):
        p, m = build(rows, cols, 1, 1)
        StreamingExecutor(
            p, m, StripeSplitter(n_splits=6), worker=w, n_workers=W
        ).run()
        # each worker writes only its strips into its own mapper buffer
        acc += m.result
    np.testing.assert_allclose(acc, whole_img, rtol=1e-5, atol=1e-4)
