"""Cross-executor differential harness — the tier-1 home of the plan-layer
equivalence guarantees (PR 3 registry sharing + windowed reads).

Every registered pipeline (P1–P7 + IO) runs against the eager pull oracle on
every engine: streaming (prefetch 0 and 2), the work-stealing thread pool,
and the shard_map SPMD executor on 2/4/8 virtual devices.  The contract:

  * all compiled executors produce BIT-IDENTICAL outputs — one registry, one
    canonical trace per signature; windowed reads make this hold for the P1
    warp too (absolute-coordinate sampling + static window shapes), and
    virtual padded strips make it hold on *ragged* splits (rows not
    divisible by the worker count) and at n=2 (no interior strip);
  * every pipeline takes the unified SPMD strip path on every column — the
    legacy closure is gone — and the second and later executors on one strip
    geometry record zero new compiles and zero new lowers (registry hits
    only, with NO n=2 exception: streaming border stripes describe against
    the virtual padded geometry exactly like the SPMD prober, so even a
    2-stripe halo run lowers the interior plan that SPMD then hits);
  * outputs equal the eager oracle bit-exactly for fusion-insensitive
    pipelines, and within float tolerance for the bicubic ones (P1/P3/P7):
    under jit XLA contracts mul+add chains into FMAs, the eager pull
    dispatches per-op, so the same math rounds ~1 ulp apart.

The SPMD device axis is parametrized {2, 4, 5, 8}: 2/4/8 divide every
case's rows (divisible column; n=2 exercises the no-interior-strip halos),
while 5 divides none of them (48- and 96-row cases alike), so the 5-device
column runs every pipeline on a ragged split with virtual pad rows.
"""
import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import PlanCache, StreamingExecutor, StripeSplitter, run_pool
from repro.raster import SyntheticScene, make_spot6_pair


def _src(rows=48, cols=32):
    return SyntheticScene(rows, cols, bands=4, dtype=np.float32)


#: name -> (builder, eager_exact); eager_exact means the jitted executors are
#: expected to match the eager pull bit-for-bit (no FMA-sensitive math)
CASES = {
    # P1's warp halo needs >= 12-row strips (96 rows / 8 workers)
    "P1": (lambda: PP.p1_orthorectification(_src(96, 64)), False),
    "P2": (lambda: PP.p2_textures(_src(), radius=2, levels=4), True),
    "P3": (lambda: PP.p3_pansharpening(*make_spot6_pair(24, 16)), False),
    "P4": (lambda: PP.p4_classification(_src()), True),
    "P5": (lambda: PP.p5_meanshift(_src(), hs=2, n_iter=2), True),
    "P6": (lambda: PP.p6_conversion(_src()), True),
    # P7 source is 24 rows -> 96 output rows: divisible at 2/4/8 devices,
    # ragged at 5 with H=20 still a multiple of the resampling ratio
    "P7": (lambda: PP.p7_resampling(_src(24, 24)), False),
    # P8/P9 read through the catalog layer (MosaicSource host-side
    # assembly); the compiled stages are pointwise, so bit-exact
    "P8": (lambda: PP.p8_mosaic(rows=48, cols=32, seed=3), True),
    "P9": (lambda: PP.p9_ndvi_composite(rows=48, cols=32, seed=5), True),
    "IO": (lambda: PP.io_passthrough(_src()), True),
}


def _assert_oracle(name, got, oracle, exact):
    if exact:
        np.testing.assert_array_equal(got, oracle, err_msg=f"{name} != oracle")
    else:
        np.testing.assert_allclose(
            got.astype(np.float64), oracle.astype(np.float64),
            rtol=1e-4, atol=1e-3, err_msg=f"{name} != oracle",
        )


# -- in-process matrix: eager oracle × streaming(0/2) × pool ------------------
@pytest.mark.parametrize("name", list(CASES))
def test_streaming_and_pool_differential(name):
    build, eager_exact = CASES[name]
    p, m = build()
    info = p.info(m)
    oracle = np.asarray(p.pull(m, info.full_region))

    cache = PlanCache()
    splitter = StripeSplitter(n_splits=6)
    res0 = StreamingExecutor(
        p, m, splitter, plan_cache=cache, prefetch=0
    ).run()
    ref = np.array(m.result)
    assert res0.cache_stats is cache.stats
    _assert_oracle(name, ref, oracle, eager_exact)
    lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles

    # second executor, same geometry: bit-identical, zero new lowers/compiles
    StreamingExecutor(p, m, splitter, plan_cache=cache, prefetch=2).run()
    np.testing.assert_array_equal(m.result, ref, err_msg=f"{name} prefetch=2")
    assert cache.stats.lowers == lowers0, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)

    res = run_pool(p, m, splitter, n_workers=3, plan_cache=cache)
    np.testing.assert_array_equal(m.result, ref, err_msg=f"{name} pool")
    assert res.cache_stats is cache.stats
    assert cache.stats.lowers == lowers0, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)


# -- SPMD matrix: 2/4/8 virtual devices (subprocess-isolated) -----------------
CODE_SPMD_DIFF = r"""
import numpy as np
from repro import pipelines as PP
from repro.core import PlanCache, StreamingExecutor, StripeSplitter
from repro.core.parallel import ParallelExecutor
from repro.raster import SyntheticScene, make_spot6_pair

N = {devices}

def src(rows=48, cols=32):
    return SyntheticScene(rows, cols, bands=4, dtype=np.float32)

CASES = {{
    "P1": (lambda: PP.p1_orthorectification(src(96, 64)), False),
    "P2": (lambda: PP.p2_textures(src(), radius=2, levels=4), True),
    "P3": (lambda: PP.p3_pansharpening(*make_spot6_pair(24, 16)), False),
    "P4": (lambda: PP.p4_classification(src()), True),
    "P5": (lambda: PP.p5_meanshift(src(), hs=2, n_iter=2), True),
    "P6": (lambda: PP.p6_conversion(src()), True),
    "P7": (lambda: PP.p7_resampling(src(24, 24)), False),
    "P8": (lambda: PP.p8_mosaic(rows=48, cols=32, seed=3), True),
    "P9": (lambda: PP.p9_ndvi_composite(rows=48, cols=32, seed=5), True),
    "IO": (lambda: PP.io_passthrough(src()), True),
}}

for name, (build, eager_exact) in CASES.items():
    p, m = build()
    info = p.info(m)
    oracle = np.asarray(p.pull(m, info.full_region))
    cache = PlanCache()
    # matching strip geometry: N stripes == N SPMD strips
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=N), plan_cache=cache, prefetch=0
    ).run()
    streamed = np.array(m.result)
    lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles
    hits0 = cache.stats.hits

    pe = ParallelExecutor(p, m, plan_cache=cache)
    res = pe.run()
    # EVERY pipeline takes the unified strip path on EVERY geometry now:
    # virtual padded strips cover ragged splits (N=5) and n=2 halos
    assert pe.plan.unified, (name, "fell off the unified strip path")
    expected_pad = (-info.rows) % N
    assert pe.plan.pad_rows == expected_pad, (name, pe.plan.pad_rows)
    assert res.cache_stats is cache.stats, name
    # the acceptance bar: the second executor records registry HITS only —
    # zero new jax traces, zero new closure trees.  No n=2 exception any
    # more: streaming border stripes describe virtually, so even a 2-stripe
    # halo run lowers the interior signature that the SPMD prober then hits.
    assert cache.stats.lowers == lowers0, (name, cache.stats)
    assert cache.stats.hits > hits0, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)
    np.testing.assert_array_equal(
        np.asarray(m.result), streamed,
        err_msg=f"{{name}}: spmd not bit-identical to streaming")
    if eager_exact:
        np.testing.assert_array_equal(
            np.asarray(m.result), oracle,
            err_msg=f"{{name}}: spmd not bit-identical to eager oracle")
    else:
        np.testing.assert_allclose(
            np.asarray(m.result).astype(np.float64), oracle.astype(np.float64),
            rtol=1e-4, atol=1e-3, err_msg=f"{{name}}: spmd != eager oracle")

    # a third executor on the same geometry reuses the registered program
    # AND the canonical strip plan: pure registry hits, whatever N
    hits1, lowers1 = cache.stats.hits, cache.stats.lowers
    ParallelExecutor(p, m, plan_cache=cache).run()
    np.testing.assert_array_equal(np.asarray(m.result), streamed)
    assert cache.stats.lowers == lowers1, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)
    assert cache.stats.hits >= hits1 + 2, (name, cache.stats)

print("SPMD_DIFF_OK", N)
"""


# 2/4/8 divide every case's rows (divisible splits; 2 = no interior strip);
# 5 divides none (48 % 5 = 3, 96 % 5 = 1) → the ragged virtual-pad column
@pytest.mark.parametrize("devices", [2, 4, 5, 8])
def test_spmd_differential_matrix(subproc, devices):
    out = subproc(CODE_SPMD_DIFF.format(devices=devices), devices=devices,
                  timeout=1800)
    assert f"SPMD_DIFF_OK {devices}" in out


# -- 2-D tile-grid column: grid SPMD vs streaming tile warm-up ----------------
# The tile-grid generalization must be invisible in the pixels AND in the
# plan cache: after a streaming warm-up on the matching Hr×Wc tile geometry,
# a ParallelExecutor over an (nr, nc) device mesh takes the unified tile
# path, records ZERO new lowers and ZERO new compiles (pure registry hits —
# every tile of the grid, ragged columns included, shares the one interior
# signature the streaming border tiles already lowered), and reproduces the
# streaming output bit-for-bit.
CODE_GRID_DIFF = r"""
import numpy as np
from repro import pipelines as PP
from repro.core import (
    PlanCache, StreamingExecutor, TileSplitter, padded_tile_grid,
)
from repro.core.parallel import ParallelExecutor
from repro.raster import SyntheticScene

NR, NC = {nr}, {nc}

def src(rows, cols):
    return SyntheticScene(rows, cols, bands=4, dtype=np.float32)

def p3_ratio2():
    # ratio-2 pansharpening: tile origins must stay multiples of the
    # resample phase, so the 52x40 output keeps Hr and Wc even on every
    # grid column (26x20 at 2x2, 52x10 at 1x4, 26x14+pad at ragged 2x3) —
    # and the 20-col XS source keeps the per-worker column pitch above the
    # 3-col bicubic halo even on the 4-column mesh
    xs = SyntheticScene(26, 20, bands=4, seed=0, name="XS")
    pan = SyntheticScene(52, 40, bands=1, seed=7, name="PAN")
    return PP.p3_pansharpening(xs, pan, ratio=2)

CASES = {{
    # 45x34 is ragged in BOTH axes on the 2x3 mesh (pad_rows=1, pad_cols=2)
    "P2": (lambda: PP.p2_textures(src(45, 34), radius=2, levels=4), True),
    "P3": (p3_ratio2, False),
    "P5": (lambda: PP.p5_meanshift(src(48, 32), hs=2, n_iter=2), True),
}}

for name, (build, eager_exact) in CASES.items():
    p, m = build()
    info = p.info(m)
    oracle = np.asarray(p.pull(m, info.full_region))
    Hr, Wc, pad_r, pad_c = padded_tile_grid(info.rows, info.cols, NR, NC)

    cache = PlanCache()
    # streaming warm-up on the SAME Hr x Wc tile geometry the mesh will use
    StreamingExecutor(
        p, m, TileSplitter(Hr, Wc), plan_cache=cache, prefetch=0
    ).run()
    streamed = np.array(m.result)
    lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles
    hits0 = cache.stats.hits
    if eager_exact:
        np.testing.assert_array_equal(
            streamed, oracle, err_msg=f"{{name}}: streaming != eager oracle")

    pe = ParallelExecutor(p, m, plan_cache=cache, grid=(NR, NC))
    res = pe.run()
    assert pe.plan.unified, (name, "fell off the unified tile path")
    assert pe.plan.grid == (NR, NC), (name, pe.plan.grid)
    assert (pe.plan.tile_rows, pe.plan.tile_cols) == (Hr, Wc), (
        name, pe.plan.tile_rows, pe.plan.tile_cols)
    assert (pe.plan.pad_rows, pe.plan.pad_cols) == (pad_r, pad_c), (
        name, pe.plan.pad_rows, pe.plan.pad_cols)
    assert res.cache_stats is cache.stats, name
    # the acceptance bar: the grid run is a PURE registry hit — all nr*nc
    # tiles (ragged edges included) resolve to the warmed interior plan
    assert cache.stats.lowers == lowers0, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)
    assert cache.stats.hits > hits0, (name, cache.stats)
    np.testing.assert_array_equal(
        np.asarray(m.result), streamed,
        err_msg=f"{{name}}: grid spmd not bit-identical to streaming")
    if eager_exact:
        np.testing.assert_array_equal(
            np.asarray(m.result), oracle,
            err_msg=f"{{name}}: grid spmd not bit-identical to eager oracle")
    else:
        np.testing.assert_allclose(
            np.asarray(m.result).astype(np.float64), oracle.astype(np.float64),
            rtol=1e-4, atol=1e-3, err_msg=f"{{name}}: grid spmd != eager oracle")

    # a second mesh run reuses the registered program AND the tile plan
    hits1, lowers1 = cache.stats.hits, cache.stats.lowers
    ParallelExecutor(p, m, plan_cache=cache, grid=(NR, NC)).run()
    np.testing.assert_array_equal(np.asarray(m.result), streamed)
    assert cache.stats.lowers == lowers1, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)
    assert cache.stats.hits >= hits1 + 2, (name, cache.stats)

print("GRID_DIFF_OK", NR, NC)
"""


# (2,2) square mesh, (1,4) pure column split (rows whole), and the ragged
# (2,3) mesh where no case's cols divide by 3 — the acceptance geometry
@pytest.mark.parametrize("grid", [(2, 2), (1, 4), (2, 3)])
def test_grid_differential_matrix(subproc, grid):
    nr, nc = grid
    out = subproc(CODE_GRID_DIFF.format(nr=nr, nc=nc), devices=nr * nc,
                  timeout=1800)
    assert f"GRID_DIFF_OK {nr} {nc}" in out


# -- Pallas column: kernel-backed pipelines × executors × pallas-interpret ----
# P2/P3/P5 are the registry pipelines with Pallas kernels; use_pallas=True on
# CPU deterministically selects interpret mode, so this column runs the SAME
# plan-layer fast path CI exercises on accelerators.  Tolerances per kernel
# (documented in tests/test_pallas_plan.py): GLCM quantizes in float32 inside
# the kernel (bin-boundary flips move normalized features by O(1/count)),
# mean-shift's hard range threshold amplifies ~1 ulp FMA differences between
# jit contexts; pansharpen is plain arithmetic.
PALLAS_CASES = {
    "P2": (lambda up: PP.p2_textures(_src(), use_pallas=up, radius=2, levels=4),
           dict(rtol=1e-3, atol=1e-2)),
    "P3": (lambda up: PP.p3_pansharpening(*make_spot6_pair(24, 16), use_pallas=up),
           dict(rtol=1e-4, atol=1e-2)),
    "P5": (lambda up: PP.p5_meanshift(_src(), use_pallas=up, hs=2, n_iter=2),
           dict(rtol=1e-4, atol=1e-2)),
}


@pytest.mark.parametrize("name", list(PALLAS_CASES))
def test_pallas_interpret_differential(name):
    """Streaming(0/2) + pool on the pallas plan: one lower+compile for the
    whole striped run (virtual borders, one fused signature), later executors
    pure registry hits, outputs within the documented kernel tolerance of the
    jnp path."""
    build, tol = PALLAS_CASES[name]
    p_ref, m_ref = build(False)
    _ = p_ref.info(m_ref)
    splitter = StripeSplitter(n_splits=6)
    StreamingExecutor(p_ref, m_ref, splitter, plan_cache=PlanCache(),
                      prefetch=0).run()
    oracle = np.array(m_ref.result)

    p, m = build(True)
    cache = PlanCache()
    StreamingExecutor(p, m, splitter, plan_cache=cache, prefetch=0).run()
    ref = np.array(m.result)
    np.testing.assert_allclose(
        ref.astype(np.float64), oracle.astype(np.float64),
        err_msg=f"{name} pallas != jnp", **tol)
    # acceptance bar: the fused path lowers + compiles exactly once
    assert cache.stats.lowers == 1, (name, cache.stats)
    assert cache.stats.compiles == 1, (name, cache.stats)
    lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles

    # second + third executors on the same geometry: registry hits only
    StreamingExecutor(p, m, splitter, plan_cache=cache, prefetch=2).run()
    np.testing.assert_array_equal(m.result, ref, err_msg=f"{name} prefetch=2")
    res = run_pool(p, m, splitter, n_workers=3, plan_cache=cache)
    np.testing.assert_array_equal(m.result, ref, err_msg=f"{name} pool")
    assert res.cache_stats.lowers == lowers0, (name, cache.stats)
    assert res.cache_stats.compiles == compiles0, (name, cache.stats)


CODE_SPMD_PALLAS = r"""
import numpy as np
from repro import pipelines as PP
from repro.core import PlanCache, StreamingExecutor, StripeSplitter
from repro.core.parallel import ParallelExecutor
from repro.raster import SyntheticScene, make_spot6_pair

def src(rows=48, cols=32):
    return SyntheticScene(rows, cols, bands=4, dtype=np.float32)

CASES = {
    "P2": (lambda up: PP.p2_textures(src(), use_pallas=up, radius=2, levels=4),
           dict(rtol=1e-3, atol=1e-2)),
    "P3": (lambda up: PP.p3_pansharpening(*make_spot6_pair(24, 16), use_pallas=up),
           dict(rtol=1e-4, atol=1e-2)),
    "P5": (lambda up: PP.p5_meanshift(src(), use_pallas=up, hs=2, n_iter=2),
           dict(rtol=1e-4, atol=1e-2)),
}

for name, (build, tol) in CASES.items():
    p, m = build(False)
    StreamingExecutor(p, m, StripeSplitter(n_splits=4), plan_cache=PlanCache(),
                      prefetch=0).run()
    oracle = np.array(m.result)

    p, m = build(True)
    cache = PlanCache()
    StreamingExecutor(p, m, StripeSplitter(n_splits=4), plan_cache=cache,
                      prefetch=0).run()
    lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles
    assert (lowers0, compiles0) == (1, 1), (name, cache.stats)

    # pallas_call traces into the shard_map program (check_rep=False) and the
    # strip plan comes straight from the registry: zero new lowers/compiles
    pe = ParallelExecutor(p, m, plan_cache=cache)
    pe.run()
    assert pe.plan.unified, (name, "fell off the unified strip path")
    assert cache.stats.lowers == lowers0, (name, cache.stats)
    assert cache.stats.compiles == compiles0, (name, cache.stats)
    np.testing.assert_allclose(
        np.asarray(m.result).astype(np.float64), oracle.astype(np.float64),
        err_msg=f"{name}: spmd-pallas != jnp", **tol)

print("SPMD_PALLAS_OK")
"""


def test_spmd_pallas_interpret_differential(subproc):
    out = subproc(CODE_SPMD_PALLAS, devices=4, timeout=1800)
    assert "SPMD_PALLAS_OK" in out


# -- pipelined-orchestrator column: mixed pool+SPMD stage DAG -----------------
# The region-granularity DAG scheduler (repro.core.dag) must be invisible in
# the pixels: a stage DAG mixing thread-pool streaming stages with a
# shard_map SPMD stage produces BIT-IDENTICAL per-stage outputs whether the
# stages run sequentially behind full barriers (the oracle) or concurrently
# with region-granularity edge streaming — and pipelining adds zero extra
# plan-cache lowers/compiles (fresh-cache counts match the oracle's exactly).
# SPMD consumers gate at stage granularity (wait_complete), SPMD producers
# commit per strip like any pool stage; both directions are covered here.
# CI runs this as its own job (-k orchestrator) so tier-1 wall time stays
# flat.
def test_orchestrator_pipelined_vs_barrier_differential():
    from repro.core import Orchestrator, PlanCache, Stage
    from repro.filters import BandMath, SobelGradient, gaussian_smoothing
    from repro.raster import ParallelRasterWriter, RasterReader

    def make_stages():
        def build_src(_inputs, out):
            p, m = PP.io_passthrough(
                _src(48, 32), mapper_factory=lambda: ParallelRasterWriter(out)
            )
            return p, m

        def build_smooth(inputs, out):
            from repro.core import Pipeline

            p = Pipeline()
            r = p.add(RasterReader(inputs["src"]))
            g = p.add(gaussian_smoothing(1.0), [r])
            m = p.add(ParallelRasterWriter(out), [g])
            return p, m

        def build_edges_spmd(inputs, out):
            from repro.core import Pipeline

            p = Pipeline()
            r = p.add(RasterReader(inputs["smooth"]))
            e = p.add(SobelGradient(), [r])
            m = p.add(ParallelRasterWriter(out), [e])
            return p, m

        def build_scale(inputs, out):
            import jax.numpy as jnp

            from repro.core import Pipeline

            p = Pipeline()
            r = p.add(RasterReader(inputs["edges"]))
            s = p.add(BandMath(lambda x: jnp.sqrt(jnp.abs(x) + 1.0),
                               out_bands=1), [r])
            m = p.add(ParallelRasterWriter(out), [s])
            return p, m

        return [
            Stage("src", build_src, n_workers=2,
                  splitter=StripeSplitter(n_splits=6)),
            Stage("smooth", build_smooth, inputs=("src",), n_workers=2,
                  splitter=StripeSplitter(n_splits=6)),
            # SPMD consumer (stage-granularity gate) AND SPMD producer
            # (per-strip commits feed the pool consumer downstream)
            Stage("edges", build_edges_spmd, inputs=("smooth",), n_workers=1,
                  executor="spmd"),
            Stage("scale", build_scale, inputs=("edges",), n_workers=3,
                  splitter=StripeSplitter(n_splits=4)),
        ]

    cache_b = PlanCache()
    with Orchestrator(make_stages(), plan_cache=cache_b) as orch:
        res = orch.run(pipelined=False)
        barrier = {k: RasterReader(v.path).read_region() for k, v in res.items()}

    cache_p = PlanCache()
    with Orchestrator(make_stages(), plan_cache=cache_p, pipelined=True,
                      queue_capacity=2) as orch:
        res = orch.run()
        pipelined = {k: RasterReader(v.path).read_region() for k, v in res.items()}
        stats = dict(orch.edge_stats)

    assert set(barrier) == set(pipelined) == {"src", "smooth", "edges", "scale"}
    for name in barrier:
        np.testing.assert_array_equal(
            pipelined[name], barrier[name],
            err_msg=f"stage {name}: pipelined != barrier oracle")
    assert cache_p.stats.lowers == cache_b.stats.lowers, (
        cache_b.stats, cache_p.stats)
    assert cache_p.stats.compiles == cache_b.stats.compiles, (
        cache_b.stats, cache_p.stats)
    # pool edges saw region-granularity traffic; the SPMD consumer's inbound
    # edge gated at stage granularity (no backpressure armed)
    assert stats[("src", "smooth")].commits > 0
    assert stats[("edges", "scale")].commits > 0
    assert stats[("src", "smooth")].releases > 0
