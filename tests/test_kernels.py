"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention as fak,
    glcm as glcmk,
    meanshift as msk,
    pansharpen as psk,
    ssd_scan as ssdk,
)
from repro.kernels import ref

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------------
# GLCM Haralick
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(16, 16), (32, 24), (40, 56)])
@pytest.mark.parametrize("radius,offset,levels", [(1, (0, 1), 4), (2, (1, 1), 8)])
@pytest.mark.parametrize("dtype", [np.float32, np.uint16])
def test_glcm_kernel_matches_ref(shape, radius, offset, levels, dtype):
    halo = radius + max(abs(offset[0]), abs(offset[1]))
    H, W = shape
    x = RNG.uniform(0, 4096, size=(H + 2 * halo, W + 2 * halo)).astype(dtype)
    got = glcmk.glcm_features(
        jnp.asarray(x.astype(np.float32)), radius, offset, levels,
        0.0, 4096.0, tile=(16, 16), interpret=True,
    )
    want = ref.glcm_features_ref(
        jnp.asarray(x.astype(np.float32)), radius, offset, levels, 0.0, 4096.0
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# Pansharpening
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape,bands", [((16, 16), 4), ((32, 48), 3), ((24, 20), 1)])
@pytest.mark.parametrize("radius", [1, 2])
def test_pansharpen_kernel_matches_ref(shape, bands, radius):
    H, W = shape
    xs = RNG.uniform(0, 4096, size=(H, W, bands)).astype(np.float32)
    pan = RNG.uniform(1, 4096, size=(H + 2 * radius, W + 2 * radius, 1)).astype(
        np.float32
    )
    got = psk.pansharpen(jnp.asarray(xs), jnp.asarray(pan), radius,
                         tile=(16, 16), interpret=True)
    want = ref.pansharpen_ref(jnp.asarray(xs), jnp.asarray(pan), radius)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


# --------------------------------------------------------------------------
# Mean shift
# --------------------------------------------------------------------------
@pytest.mark.parametrize("hs,n_iter", [(1, 1), (2, 3)])
@pytest.mark.parametrize("bands", [1, 3])
def test_meanshift_kernel_matches_ref(hs, n_iter, bands):
    H, W = 24, 20
    x = RNG.uniform(0, 500, size=(H + 2 * hs, W + 2 * hs, bands)).astype(np.float32)
    got = msk.meanshift(jnp.asarray(x), hs, 120.0, n_iter,
                        tile=(8, 8), interpret=True)
    want = ref.meanshift_ref(jnp.asarray(x), hs, 120.0, n_iter)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("S,D,blocks", [(128, 32, (32, 32)), (256, 64, (64, 128))])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(S, D, blocks, causal, dtype):
    BH = 3
    q = jnp.asarray(RNG.normal(size=(BH, S, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(BH, S, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(BH, S, D)), dtype)
    got = fak.flash_attention(q, k, v, causal=causal,
                              block_q=blocks[0], block_k=blocks[1],
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


# --------------------------------------------------------------------------
# SSD intra-chunk
# --------------------------------------------------------------------------
@pytest.mark.parametrize("L,P,N", [(16, 8, 4), (32, 16, 8), (64, 32, 16)])
def test_ssd_kernel_matches_ref(L, P, N):
    BHC = 5
    x = jnp.asarray(RNG.normal(size=(BHC, L, P)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (BHC, L)).astype(np.float32))
    loga = -dt * jnp.asarray(RNG.uniform(0.2, 1.0, (BHC, L)).astype(np.float32))
    cum = jnp.cumsum(loga, axis=1)
    B = jnp.asarray(RNG.normal(size=(BHC, L, N)).astype(np.float32))
    C = jnp.asarray(RNG.normal(size=(BHC, L, N)).astype(np.float32))
    y1, s1 = ssdk.ssd_intra_chunk(x, dt, cum, B, C, interpret=True)
    y2, s2 = ref.ssd_intra_ref(x, dt, cum, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_composes_with_chunked_scan():
    """Kernel output + jnp inter-chunk recurrence == full SSD reference."""
    from repro.models.ssm import ssd_reference

    Bz, S, H, P, N, Lc = 2, 64, 2, 8, 4, 16
    x = jnp.asarray(RNG.normal(size=(Bz, S, H, P)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bz, S, H)).astype(np.float32))
    A = jnp.asarray(RNG.uniform(-1.5, -0.2, (H,)).astype(np.float32))
    Bm = jnp.asarray(RNG.normal(size=(Bz, S, 1, N)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(size=(Bz, S, 1, N)).astype(np.float32))
    D = jnp.asarray(RNG.normal(size=(H,)).astype(np.float32))

    # kernel path: reshape to (B·H·nc, L, ·) cells
    nc = S // Lc
    loga = dt * A[None, None, :]
    cum = jnp.cumsum(loga.reshape(Bz, nc, Lc, H), axis=2)
    xc = x.reshape(Bz, nc, Lc, H, P)
    dtc = dt.reshape(Bz, nc, Lc, H)
    Bc = jnp.repeat(Bm, H, axis=2).reshape(Bz, nc, Lc, H, N)
    Cc = jnp.repeat(Cm, H, axis=2).reshape(Bz, nc, Lc, H, N)

    def cells(a, feat):  # (B,nc,L,H,·) → (B·H·nc, L, ·)
        a = jnp.moveaxis(a, 3, 1)  # B, H, nc, L, ·
        return a.reshape((Bz * H * nc, Lc) + feat)

    y_i, s_c = ssdk.ssd_intra_chunk(
        cells(xc, (P,)), cells(dtc, ()), cells(cum, ()),
        cells(Bc, (N,)), cells(Cc, (N,)), interpret=True,
    )
    y_i = y_i.reshape(Bz, H, nc, Lc, P)
    s_c = s_c.reshape(Bz, H, nc, N, P)

    # inter-chunk recurrence in jnp
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)
    prev = jnp.zeros((Bz, H, N, P))
    y_total = []
    for c in range(nc):
        yc = y_i[:, :, c]  # (B,H,L,P)
        cw = Cc[:, c] * jnp.exp(cum[:, c])[..., None]  # (B,L,H,N)
        y_inter = jnp.einsum("blhn,bhnp->bhlp", cw, prev)
        y_total.append(yc + y_inter)
        prev = prev * chunk_decay[:, c][..., None, None] + s_c[:, :, c]
    y = jnp.stack(y_total, axis=2).reshape(Bz, H, S, P).transpose(0, 2, 1, 3)
    y = y + D[None, None, :, None] * x
    want = ssd_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=3e-4, atol=3e-4)
