"""RTIC tiled container + the Source/Sink protocol (cloud-native IO).

Covers the PR-10 acceptance bars: TileWriter → TiledSource round trip
(property test over tile geometry × strip covers), stored overviews
bit-equal to on-the-fly decimation, the range-read backends (file + the
in-memory remote stand-in with request counters), async read-ahead,
DecimatedSource edge behavior (ragged clamping, origin rescaling), the
protocol coercers / capability flags / deprecated free-function wrappers,
``run_pipeline(sink=...)``, the catalog layer behind P8/P9, and the
streamed-then-SPMD zero-new-lowers guarantee over a TiledSource.
"""
import os
import time

import numpy as np
import pytest

try:  # only the property test needs hypothesis; the rest must always run
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro import pipelines as PP
from repro.core import (
    ImageInfo,
    ImageRegion,
    Pipeline,
    StreamingExecutor,
    StripeSplitter,
    whole,
)
from repro.core.process_object import GeoTransform
from repro.core.region import tile_cover
from repro.raster import (
    CAP_PYRAMIDAL,
    CAP_RANGE_READABLE,
    CAP_TILED,
    ArraySource,
    DecimatedSource,
    MemoryRangeReader,
    MosaicSource,
    ParallelRasterWriter,
    RasterReader,
    SceneCatalog,
    SceneEntry,
    SyntheticScene,
    TiledSource,
    TileWriter,
    as_sink,
    as_source,
)
from repro.raster import io as rio


def _write_rtic(path, data, tile_rows=16, tile_cols=None, levels=None,
                strip_rows=7, geo=None):
    """Write ``data`` through TileWriter in full-width strips of
    ``strip_rows`` (the executors' consume pattern)."""
    rows, cols, bands = data.shape
    info = ImageInfo(
        rows, cols, bands, data.dtype,
        geo or GeoTransform(1.0, 2.0, 6.0, -6.0),
    )
    w = TileWriter(path, tile_rows, tile_cols, levels=levels)
    w.begin(info)
    r0 = 0
    while r0 < rows:
        h = min(strip_rows, rows - r0)
        w.consume(ImageRegion((r0, 0), (h, cols)), data[r0:r0 + h])
        r0 += h
    w.end()
    return info


def _rand(rows, cols, bands=3, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    if np.issubdtype(np.dtype(dtype), np.integer):
        return rng.integers(0, 1000, size=(rows, cols, bands)).astype(dtype)
    return rng.normal(size=(rows, cols, bands)).astype(dtype)


# -- round trip ---------------------------------------------------------------

def test_roundtrip_exact(tmp_path):
    path = str(tmp_path / "a.rtic")
    data = _rand(50, 37, 3)
    info = _write_rtic(path, data, tile_rows=16, tile_cols=13)
    src = TiledSource(path)
    try:
        got = src.read_region()
        np.testing.assert_array_equal(got, data)
        out = src.info()
        assert (out.rows, out.cols, out.bands) == (50, 37, 3)
        assert out.geo.spacing_x == info.geo.spacing_x
        # windowed read straddling tile boundaries
        win = ImageRegion((10, 8), (23, 21))
        np.testing.assert_array_equal(
            src.read_region(win), data[10:33, 8:29]
        )
        # jax-side generate (the executor path) agrees with read_region
        np.testing.assert_array_equal(np.asarray(src.generate(win)),
                                      data[10:33, 8:29])
    finally:
        src.close()


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(1, 60), st.integers(1, 50), st.integers(1, 20),
        st.integers(1, 20), st.integers(1, 13), st.booleans(),
    )
    def test_roundtrip_property(tmp_path_factory, rows, cols, tile_r,
                                tile_c, strip_rows, reverse):
        _check_roundtrip(tmp_path_factory, rows, cols, tile_r, tile_c,
                         strip_rows, reverse)

else:  # stay visible as a skip (not silently uncollected) without hypothesis

    @pytest.mark.skip(reason="property test needs hypothesis")
    def test_roundtrip_property():
        pass


# deterministic corner geometries — always run, with or without hypothesis
@pytest.mark.parametrize(
    "rows,cols,tile_r,tile_c,strip_rows,reverse",
    [
        (1, 1, 1, 1, 1, False),       # degenerate single pixel
        (33, 17, 8, 5, 4, True),      # ragged both axes, reverse order
        (60, 50, 20, 20, 13, False),  # strips never tile-aligned
        (10, 31, 16, 4, 3, True),     # tile taller than the image
    ],
)
def test_roundtrip_cases(tmp_path_factory, rows, cols, tile_r, tile_c,
                         strip_rows, reverse):
    _check_roundtrip(tmp_path_factory, rows, cols, tile_r, tile_c,
                     strip_rows, reverse)


def _check_roundtrip(tmp_path_factory, rows, cols, tile_r, tile_c,
                     strip_rows, reverse):
    tmp = tmp_path_factory.mktemp("rt")
    path = str(tmp / "p.rtic")
    data = _rand(rows, cols, bands=2, seed=rows * 61 + cols)
    info = ImageInfo(rows, cols, 2, data.dtype)
    w = TileWriter(path, tile_r, tile_c)
    w.begin(info)
    strips = []
    r0 = 0
    while r0 < rows:
        h = min(strip_rows, rows - r0)
        strips.append((ImageRegion((r0, 0), (h, cols)), data[r0:r0 + h]))
        r0 += h
    # consume order must not matter (tiles append when fully covered,
    # stragglers flush on end)
    for region, block in reversed(strips) if reverse else strips:
        w.consume(region, block)
    w.end()
    src = TiledSource(path)
    try:
        np.testing.assert_array_equal(src.read_region(), data)
        # every stored overview level equals the decimation contract
        flat = ArraySource(data)
        for lv in range(1, src._c.n_levels):
            np.testing.assert_array_equal(
                TiledSource(src._c, level=lv).read_region(),
                DecimatedSource(flat, 2 ** lv).read_region(),
            )
    finally:
        src.close()


def test_tile_unaligned_partial_covers(tmp_path):
    """Disjoint non-strip covers (2-D tiles smaller than the container's
    tile grid) still reassemble exactly — pending buffers merge them."""
    path = str(tmp_path / "t.rtic")
    data = _rand(21, 19, 2, seed=5)
    info = ImageInfo(21, 19, 2, data.dtype)
    w = TileWriter(path, tile_rows=8, tile_cols=8)
    w.begin(info)
    pieces = list(tile_cover(whole(21, 19), 5, 6, bounds=whole(21, 19)))
    for _, _, region in reversed(pieces):
        w.consume(region, data[region.slices()])
    w.end()
    src = TiledSource(path)
    try:
        np.testing.assert_array_equal(src.read_region(), data)
    finally:
        src.close()


# -- overviews ----------------------------------------------------------------

def test_overview_levels_match_decimated(tmp_path):
    path = str(tmp_path / "o.rtic")
    data = _rand(70, 45, 2, seed=3)
    _write_rtic(path, data, tile_rows=16, levels=3)
    src = TiledSource(path)
    try:
        assert src.overview(0) is src
        flat = ArraySource(data)
        for lv in (1, 2):
            ov = src.overview(lv)
            assert isinstance(ov, TiledSource)
            np.testing.assert_array_equal(
                ov.read_region(),
                DecimatedSource(flat, 2 ** lv).read_region(),
            )
            # level info scales geo spacing by 2**lv
            assert ov.info().geo.spacing_x == src.info().geo.spacing_x * 2 ** lv
        # past the deepest stored level: decimate the deepest level; the
        # ceil-division composition keeps the pixel contract exact
        ov3 = src.overview(3)
        assert isinstance(ov3, DecimatedSource)
        np.testing.assert_array_equal(
            ov3.read_region(), DecimatedSource(flat, 8).read_region()
        )
        # an overview view of an overview composes levels
        np.testing.assert_array_equal(
            src.overview(1).overview(1).read_region(),
            DecimatedSource(flat, 4).read_region(),
        )
    finally:
        src.close()


def test_auto_level_selection(tmp_path):
    """Default pyramid depth: add levels until the coarsest fits one tile."""
    path = str(tmp_path / "auto.rtic")
    _write_rtic(path, _rand(100, 80, 1), tile_rows=16)
    src = TiledSource(path)
    try:
        # 100x80 → 50x40 → 25x20 → 13x10 (fits 16x16): 4 levels
        assert src._c.n_levels == 4
        lv = src._c.levels[-1]
        assert max(lv["rows"], lv["cols"]) <= 16
    finally:
        src.close()


def test_zoom_view_routes_through_overview(tmp_path):
    from repro.serve.tiles import zoom_view

    path = str(tmp_path / "z.rtic")
    data = _rand(64, 48, 2, seed=9)
    _write_rtic(path, data, tile_rows=16, levels=2)
    src = TiledSource(path)
    try:
        assert zoom_view(src, 0) is src
        z1 = zoom_view(src, 1)
        assert isinstance(z1, TiledSource)  # stored level, not a wrap
        np.testing.assert_array_equal(z1.read_region(), data[::2, ::2])
        # non-pyramidal sources fall back to DecimatedSource
        flat = ArraySource(data)
        zf = zoom_view(flat, 1)
        assert isinstance(zf, DecimatedSource)
        np.testing.assert_array_equal(zf.read_region(), data[::2, ::2])
    finally:
        src.close()


# -- range backends + read-ahead ----------------------------------------------

def test_memory_range_reader_counts_requests(tmp_path):
    path = str(tmp_path / "m.rtic")
    data = _rand(40, 40, 1, seed=2)
    _write_rtic(path, data, tile_rows=16, levels=1)
    reader = MemoryRangeReader.from_file(path)
    src = TiledSource(reader)
    try:
        base = reader.requests  # header + footer index
        assert base == 2
        win = ImageRegion((0, 0), (10, 10))  # one tile
        np.testing.assert_array_equal(src.read_region(win), data[:10, :10])
        assert reader.requests == base + 1
        # cached tile: a second read costs zero range requests
        np.testing.assert_array_equal(src.read_region(win), data[:10, :10])
        assert reader.requests == base + 1
        assert src.stats()["tile_hits"] >= 1
        # whole image: 3x3 tile grid, 8 more fetches
        np.testing.assert_array_equal(src.read_region(), data)
        assert reader.requests == base + 9
        assert reader.bytes_read > 0
    finally:
        src.close()
    assert src._c.owns_reader is False


def test_file_range_reader_stats(tmp_path):
    path = str(tmp_path / "f.rtic")
    data = _rand(20, 20, 1)
    _write_rtic(path, data, tile_rows=16, levels=1)
    src = TiledSource(path)  # FileRangeReader under the hood
    try:
        np.testing.assert_array_equal(src.read_region(), data)
        s = src.stats()
        assert s["requests"] >= 2 + 4  # header + index + 2x2 tiles
        assert s["tile_misses"] == 4
    finally:
        src.close()


def test_read_ahead_prefetches_tiles(tmp_path):
    path = str(tmp_path / "ra.rtic")
    data = _rand(48, 32, 2, seed=4)
    _write_rtic(path, data, tile_rows=16, levels=1)
    reader = MemoryRangeReader.from_file(path)
    src = TiledSource(reader)
    try:
        regions = [ImageRegion((r, 0), (12, 32)) for r in (0, 12, 24, 36)]
        n = src.read_ahead(regions)
        assert n == 6  # 3x2 tile grid, deduplicated across regions
        assert src.stats()["readahead_scheduled"] == 6
        src._c.drain()
        deadline = time.monotonic() + 2.0
        while (src.stats()["cached_tiles"] < 6
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert src.stats()["cached_tiles"] == 6
        # re-scheduling cached tiles is a no-op
        assert src.read_ahead(regions) == 0
        hits0 = src.stats()["tile_hits"]
        for region in regions:
            np.testing.assert_array_equal(
                src.read_region(region), data[region.slices()]
            )
        assert src.stats()["tile_hits"] >= hits0 + 6
    finally:
        src.close()


def test_rejects_out_of_image_and_bad_magic(tmp_path):
    path = str(tmp_path / "b.rtic")
    data = _rand(20, 20, 1)
    _write_rtic(path, data, tile_rows=16)
    src = TiledSource(path)
    try:
        with pytest.raises(ValueError):
            src.read_region(ImageRegion((10, 10), (20, 20)))
        with pytest.raises(ValueError):
            TiledSource(src._c, level=9)
    finally:
        src.close()
    flat = str(tmp_path / "x.rtif")
    rio.create(flat, ImageInfo(4, 4, 1, np.uint8))
    with pytest.raises(ValueError):
        TiledSource(flat)


# -- DecimatedSource edge behavior (satellite: zoom-view correctness) ---------

def test_decimated_ragged_edges_and_origins():
    base = SyntheticScene(29, 23, bands=2, dtype=np.float32, seed=1)
    full = np.asarray(base.generate(whole(29, 23)))
    d = DecimatedSource(base, 4)
    info = d.output_info()
    # ceil-division dims: the ragged last row/col of samples is kept
    assert (info.rows, info.cols) == (8, 6)
    assert info.geo.spacing_x == base.output_info().geo.spacing_x * 4
    got = d.read_region()
    np.testing.assert_array_equal(got, full[::4, ::4])
    # ragged bottom-right window: the scaled base window clamps to the
    # image (rows 24..29 from a nominal 24..32) and still yields 2x2
    win = ImageRegion((6, 4), (2, 2))
    np.testing.assert_array_equal(d.read_region(win), got[6:8, 4:6])
    # origin rescaling: a needs_origin base samples absolute coordinates,
    # so every windowed read equals the matching full-read slice
    for win in (ImageRegion((0, 0), (3, 3)), ImageRegion((5, 1), (3, 5)),
                ImageRegion((7, 5), (1, 1))):
        np.testing.assert_array_equal(d.read_region(win), got[win.slices()])


def test_decimated_overview_composes_factors():
    base = SyntheticScene(57, 41, bands=1, dtype=np.float32, seed=2)
    d2 = DecimatedSource(base, 2)
    ov = d2.overview(1)
    # one flat strided view of the base, not a nested wrap
    assert isinstance(ov, DecimatedSource) and ov.base is base
    assert ov.factor == 4
    np.testing.assert_array_equal(
        ov.read_region(), DecimatedSource(base, 4).read_region()
    )
    # ceil-division composes: nested view pixels are identical
    nested = DecimatedSource(d2, 2)
    np.testing.assert_array_equal(ov.read_region(), nested.read_region())
    assert d2.overview(0) is d2


# -- protocol surface ---------------------------------------------------------

def test_capabilities():
    scene = SyntheticScene(8, 8, bands=1, dtype=np.float32)
    assert scene.capabilities() == frozenset()  # the protocol default
    assert TileWriter("x.rtic").capabilities() == {CAP_TILED, CAP_PYRAMIDAL}
    assert MemoryRangeReader(b"").size() == 0  # remote stand-in is trivial


def test_as_source_sniffs_container_magic(tmp_path):
    data = _rand(12, 10, 2, seed=6)
    # RTIF path → RasterReader
    flat = str(tmp_path / "flat.rtif")
    info = ImageInfo(12, 10, 2, data.dtype)
    rio.create(flat, info)
    rio.write_strip(flat, info, whole(12, 10), data)
    s = as_source(flat)
    assert isinstance(s, RasterReader)
    assert s.capabilities() == {CAP_RANGE_READABLE}
    np.testing.assert_array_equal(s.read_region(), data)
    # RTIC path → TiledSource (magic sniff, not extension)
    tiled = str(tmp_path / "tiled.bin")
    _write_rtic(tiled, data, tile_rows=8)
    t = as_source(tiled)
    assert isinstance(t, TiledSource)
    assert t.capabilities() == {CAP_TILED, CAP_PYRAMIDAL, CAP_RANGE_READABLE}
    np.testing.assert_array_equal(t.read_region(), data)
    t.close()
    # ndarray → ArraySource; Source passthrough; everything else rejects
    a = as_source(data)
    assert isinstance(a, ArraySource)
    scene = SyntheticScene(4, 4)
    assert as_source(scene) is scene
    with pytest.raises(TypeError):
        as_source(42)


def test_as_sink_dispatch(tmp_path):
    t = as_sink(str(tmp_path / "o.rtic"))
    assert isinstance(t, TileWriter)
    f = as_sink(str(tmp_path / "o.rtif"))
    assert isinstance(f, ParallelRasterWriter)
    assert as_sink(t) is t
    with pytest.raises(TypeError):
        as_sink(42)


def test_read_write_many(tmp_path):
    data = _rand(24, 16, 2, seed=8)
    info = ImageInfo(24, 16, 2, data.dtype)
    regions = StripeSplitter(n_splits=4).split(whole(24, 16), info)
    path = str(tmp_path / "many.rtif")
    w = ParallelRasterWriter(path)
    w.begin(info)
    w.write_many([(r, data[r.slices()]) for r in regions], n_writers=3)
    w.end()
    reader = RasterReader(path)
    blocks = reader.read_many(regions, n_readers=3)
    for r, b in zip(regions, blocks):
        np.testing.assert_array_equal(b, data[r.slices()])
    np.testing.assert_array_equal(reader.read_region(), data)


def test_deprecated_wrappers_delegate(tmp_path):
    data = _rand(12, 8, 2, seed=7)
    info = ImageInfo(12, 8, 2, data.dtype)
    strips = [
        (r, data[r.slices()])
        for r in StripeSplitter(n_splits=3).split(whole(12, 8), info)
    ]
    path = str(tmp_path / "dep.rtif")
    with pytest.warns(DeprecationWarning):
        rio.parallel_write(path, info, strips, n_writers=2)
    with pytest.warns(DeprecationWarning):
        got = rio.read_region(path)
    np.testing.assert_array_equal(got, data)
    with pytest.warns(DeprecationWarning):
        blocks = rio.parallel_read(path, [r for r, _ in strips], n_readers=2)
    for (r, b), g in zip(strips, blocks):
        np.testing.assert_array_equal(g, b)


# -- pipeline integration -----------------------------------------------------

def test_run_pipeline_sink_writes_tiled(tmp_path):
    out = str(tmp_path / "p6.rtic")
    src = SyntheticScene(40, 24, bands=3, dtype=np.float32, seed=1)
    res, mapper = PP.run_pipeline(
        "P6", src, sink=out, splitter=StripeSplitter(n_splits=4)
    )
    assert isinstance(mapper, TileWriter)
    p, m = PP.p6_conversion(src)
    oracle = np.asarray(p.pull(m, p.info(m).full_region))
    back = as_source(out)
    assert isinstance(back, TiledSource)
    try:
        np.testing.assert_array_equal(back.read_region(), oracle)
        # the written pyramid serves zooms bit-equal to decimating the output
        np.testing.assert_array_equal(
            back.overview(1).read_region(), oracle[::2, ::2]
        )
    finally:
        back.close()


def test_run_pipeline_sink_flat_and_errors(tmp_path):
    out = str(tmp_path / "io.rtif")
    src = SyntheticScene(16, 12, bands=2, dtype=np.float32)
    PP.run_pipeline("IO", src, sink=out, splitter=StripeSplitter(n_splits=2))
    np.testing.assert_array_equal(
        RasterReader(out).read_region(),
        np.asarray(src.generate(whole(16, 12))),
    )
    with pytest.raises(ValueError):
        PP.run_pipeline("IO", src, sink=out, mapper_factory=lambda: None)
    pair = PP.io_passthrough(src)
    with pytest.raises(ValueError):
        PP.run_pipeline(pair, sink=out)


def test_tiled_source_feeds_pipeline(tmp_path):
    """TiledSource is a first-class pipeline source: streaming over it
    equals the eager pull, and the streaming engine's read-ahead hook
    fires (region schedule handed to the source before the loop)."""
    path = str(tmp_path / "feed.rtic")
    data = _rand(48, 32, 4, seed=11)
    _write_rtic(path, data, tile_rows=16)
    oracle_src = TiledSource(path)
    try:
        p, m = PP.p6_conversion(oracle_src)
        oracle = np.asarray(p.pull(m, p.info(m).full_region))
    finally:
        oracle_src.close()
    src = TiledSource(path)  # fresh container: nothing cached yet
    try:
        p2, m2 = PP.p6_conversion(src)
        StreamingExecutor(p2, m2, StripeSplitter(n_splits=4)).run()
        np.testing.assert_array_equal(np.asarray(m2.result), oracle)
        assert src.stats()["readahead_scheduled"] > 0
    finally:
        src.close()


# -- catalog layer (P8/P9) ----------------------------------------------------

def test_mosaic_later_scene_wins():
    a = ArraySource(np.full((4, 4, 1), 1.0, np.float32))
    b = ArraySource(np.full((4, 4, 1), 2.0, np.float32))
    cat = SceneCatalog([
        SceneEntry(a, ImageRegion((0, 0), (4, 4))),
        SceneEntry(b, ImageRegion((2, 2), (4, 4))),
    ])
    src = MosaicSource(cat)
    img = np.asarray(src.generate(src.output_info().full_region))
    assert img.shape == (6, 6, 1)  # union bounding box
    assert img[0, 0, 0] == 1.0
    assert img[3, 3, 0] == 2.0  # overlap: catalog order, later wins
    assert img[5, 0, 0] == 0.0  # uncovered canvas: fill value
    # windowed reads reassemble identically (region independence)
    win = ImageRegion((1, 1), (3, 4))
    np.testing.assert_array_equal(
        np.asarray(src.generate(win)), img[win.slices()]
    )
    assert len(cat.select(ImageRegion((0, 0), (2, 2)))) == 1
    assert len(cat.select(ImageRegion((2, 2), (2, 2)))) == 2


def test_scene_entry_validates_dims():
    a = ArraySource(np.zeros((4, 4, 1), np.float32))
    with pytest.raises(ValueError):
        SceneEntry(a, ImageRegion((0, 0), (5, 4)))


def test_p9_accepts_catalog_and_explicit_scenes():
    from repro.raster import demo_time_series

    cat = demo_time_series(24, 16, periods=2, seed=3)
    p1, m1 = PP.p9_ndvi_composite(cat)
    r1 = np.asarray(p1.pull(m1, p1.info(m1).full_region))
    p2, m2 = PP.p9_ndvi_composite(*[e.source for e in cat.by_time()])
    r2 = np.asarray(p2.pull(m2, p2.info(m2).full_region))
    np.testing.assert_array_equal(r1, r2)
    assert r1.shape == (24, 16, 1)


# -- cross-executor: tiled reads hit the shared plan registry -----------------
CODE_TILED_SPMD = r"""
import os, tempfile
import numpy as np
from repro import pipelines as PP
from repro.core import ImageInfo, PlanCache, StreamingExecutor, StripeSplitter
from repro.core.parallel import ParallelExecutor
from repro.raster import SyntheticScene, TiledSource, TileWriter

tmp = tempfile.mkdtemp()
path = os.path.join(tmp, "scene.rtic")
scene = SyntheticScene(48, 32, bands=4, dtype=np.float32)
info = scene.output_info()
data = np.asarray(scene.generate(info.full_region))
w = TileWriter(path, tile_rows=16, levels=2)
w.begin(info)
w.consume(info.full_region, data)
w.end()

src = TiledSource(path)
p, m = PP.p2_textures(src, radius=2, levels=4)

cache = PlanCache()
StreamingExecutor(p, m, StripeSplitter(n_splits=4), plan_cache=cache,
                  prefetch=0).run()
streamed = np.array(m.result)
# the streaming engine handed its region schedule to the source BEFORE the
# region loop (fresh container: nothing was cached yet)
assert src.stats()["readahead_scheduled"] > 0, src.stats()
oracle = np.asarray(p.pull(m, p.info(m).full_region))
np.testing.assert_array_equal(streamed, oracle)
lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles

# SPMD on the matching strip geometry: pure registry hits — the tiled
# read_record is part of the signature, so the hit is exact, not aliased
pe = ParallelExecutor(p, m, plan_cache=cache)
pe.run()
assert pe.plan.unified, "fell off the unified strip path"
assert cache.stats.lowers == lowers0, cache.stats
assert cache.stats.compiles == compiles0, cache.stats
np.testing.assert_array_equal(np.asarray(m.result), streamed)
src.close()
print("TILED_SPMD_OK")
"""


def test_tiled_streamed_then_spmd_zero_new_lowers(subproc):
    out = subproc(CODE_TILED_SPMD, devices=4, timeout=1800)
    assert "TILED_SPMD_OK" in out
