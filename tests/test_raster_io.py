"""RTIF container + strip-parallel writer (the paper's MPI-IO analogue)."""
import os

import numpy as np
import pytest

try:  # only the property test needs hypothesis; the rest must always run
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import ImageRegion, ImageInfo, StripeSplitter, whole
from repro.core.process_object import GeoTransform
from repro.raster import io as rio
from repro.raster import RasterReader, ParallelRasterWriter, SyntheticScene
from repro.core import Pipeline, StreamingExecutor


def _read(path, region=None):
    """Protocol read (the deprecated free function has its own test in
    test_tiled_io.py)."""
    return RasterReader(path).read_region(region)


def test_roundtrip(tmp_path):
    path = str(tmp_path / "img.rtif")
    info = ImageInfo(50, 40, 3, np.uint16, GeoTransform(1, 2, 6.0, -6.0))
    data = np.arange(50 * 40 * 3, dtype=np.uint16).reshape(50, 40, 3)
    rio.create(path, info)
    rio.write_strip(path, info, whole(50, 40), data)
    got = _read(path)
    np.testing.assert_array_equal(got, data)
    info2 = rio.read_info(path)
    assert (info2.rows, info2.cols, info2.bands) == (50, 40, 3)
    assert info2.geo.spacing_x == 6.0


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 12), st.integers(10, 80))
    def test_parallel_strip_writes_equal_serial(tmp_path_factory, n_writers, rows):
        _check_parallel_strip_writes(tmp_path_factory, n_writers, rows)

else:  # stay visible as a skip (not silently uncollected) without hypothesis

    @pytest.mark.skip(reason="property test needs hypothesis")
    def test_parallel_strip_writes_equal_serial():
        pass


def _check_parallel_strip_writes(tmp_path_factory, n_writers, rows):
    tmp = tmp_path_factory.mktemp("pw")
    info = ImageInfo(rows, 17, 2, np.float32)
    data = np.random.default_rng(0).normal(size=(rows, 17, 2)).astype(np.float32)
    regions = StripeSplitter(n_splits=min(n_writers * 2, rows)).split(
        whole(rows, 17), info
    )
    strips = [(r, data[r.slices()]) for r in regions]
    path = str(tmp / "par.rtif")
    w = ParallelRasterWriter(path)
    w.begin(info)
    w.write_many(strips, n_writers=n_writers)
    w.end()
    np.testing.assert_array_equal(_read(path), data)


def test_windowed_read(tmp_path):
    path = str(tmp_path / "img.rtif")
    info = ImageInfo(30, 20, 1, np.int32)
    data = np.arange(600, dtype=np.int32).reshape(30, 20, 1)
    rio.create(path, info)
    rio.write_strip(path, info, whole(30, 20), data)
    win = ImageRegion((5, 3), (10, 7))
    np.testing.assert_array_equal(_read(path, win), data[5:15, 3:10])


def test_reader_writer_pipeline(tmp_path):
    """Full loop: synthetic scene → parallel writer → reader → identical."""
    src_path = str(tmp_path / "src.rtif")
    p = Pipeline()
    s = p.add(SyntheticScene(40, 30, bands=2, dtype=np.float32))
    w = p.add(ParallelRasterWriter(src_path), [s])
    StreamingExecutor(p, w, StripeSplitter(n_splits=4)).run()

    # read back through a reader-based pipeline
    p2 = Pipeline()
    r = p2.add(RasterReader(src_path))
    from repro.raster import MemoryMapper

    m = p2.add(MemoryMapper(), [r])
    StreamingExecutor(p2, m, StripeSplitter(n_splits=3)).run()
    direct = np.asarray(s.generate(whole(40, 30)))
    np.testing.assert_allclose(m.result, direct, rtol=1e-6)


def test_strip_must_span_full_width(tmp_path):
    path = str(tmp_path / "img.rtif")
    info = ImageInfo(10, 10, 1, np.uint8)
    rio.create(path, info)
    with pytest.raises(ValueError):
        rio.write_strip(
            path, info, ImageRegion((0, 2), (5, 5)), np.zeros((5, 5, 1), np.uint8)
        )


class RecordingStripWriter(rio.StripWriter):
    """Counts physical pwrite syscalls (one `calls` entry per kernel write)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.calls = []

    def _pwrite_all(self, view, offset):
        self.calls.append((offset, len(view)))
        super()._pwrite_all(view, offset)


def _strips(info, data, n):
    return [
        (r, data[r.slices()])
        for r in StripeSplitter(n_splits=n).split(whole(info.rows, info.cols), info)
    ]


needs_pwrite = pytest.mark.skipif(
    not hasattr(os, "pwrite"), reason="coalescing rides the POSIX pwrite path"
)


@needs_pwrite
def test_strip_writer_coalesces_contiguous_runs(tmp_path):
    """Adjacent full-width strips written in order collapse into ONE pwrite
    (RTIF strips are contiguous on disk), verified by a recording fake."""
    path = str(tmp_path / "c.rtif")
    info = ImageInfo(32, 10, 2, np.float32)
    data = np.random.default_rng(1).normal(size=(32, 10, 2)).astype(np.float32)
    with RecordingStripWriter(path, info) as w:
        for region, block in _strips(info, data, 8):
            w.write(region, block)
    assert len(w.calls) == 1  # 8 strips → 1 syscall
    assert w.calls[0] == (rio.HEADER_BYTES, data.nbytes)
    np.testing.assert_array_equal(_read(path), data)


@needs_pwrite
def test_strip_writer_flushes_on_gap_and_cap(tmp_path):
    path = str(tmp_path / "g.rtif")
    info = ImageInfo(32, 10, 1, np.float32)
    data = np.random.default_rng(2).normal(size=(32, 10, 1)).astype(np.float32)
    strips = _strips(info, data, 8)

    # non-adjacent order: every write breaks the run → one syscall per strip
    with RecordingStripWriter(path, info) as w:
        for region, block in reversed(strips):
            w.write(region, block)
    assert len(w.calls) == len(strips)
    np.testing.assert_array_equal(_read(path), data)

    # byte cap bounds buffered memory: 2 strips per flush → 4 syscalls
    cap = 2 * strips[0][1].nbytes
    with RecordingStripWriter(path, info, coalesce_bytes=cap) as w:
        for region, block in strips:
            w.write(region, block)
    assert len(w.calls) == 4
    np.testing.assert_array_equal(_read(path), data)

    # coalesce_bytes=0 keeps the seed's strict one-syscall-per-strip path
    with RecordingStripWriter(path, info, coalesce_bytes=0) as w:
        for region, block in strips:
            w.write(region, block)
    assert len(w.calls) == len(strips)


@needs_pwrite
def test_strip_writer_coalescing_disabled_writes_through(tmp_path):
    """``coalesce_bytes=0``: every strip hits the disk synchronously (no
    pending run, so data is visible BEFORE flush/close), out-of-order and
    mutated-buffer writes are safe (the zero-buffering path never holds a
    view of the caller's array), and the final image is exact — including
    non-full-width tile regions, which take the row-segment path."""
    path = str(tmp_path / "nc.rtif")
    info = ImageInfo(16, 6, 2, np.float32)
    data = np.random.default_rng(7).normal(size=(16, 6, 2)).astype(np.float32)
    strips = _strips(info, data, 4)
    w = RecordingStripWriter(path, info, coalesce_bytes=0)
    try:
        region0, block0 = strips[0]
        buf = np.array(block0)
        w.write(region0, buf)
        buf[:] = -1.0  # caller reuses its buffer: already-written data stays
        # visible immediately — the disabled path buffers nothing
        np.testing.assert_array_equal(_read(path, region0), block0)
        for region, block in reversed(strips[1:]):  # out-of-order is fine
            w.write(region, block)
        np.testing.assert_array_equal(_read(path), data)
        assert len(w.calls) == len(strips)  # one syscall per strip, no runs
        # a tile write (not full-width) goes through row segments
        tile = ImageRegion((2, 2), (3, 3))
        patch = np.full((3, 3, 2), 9.0, np.float32)
        w.write(tile, patch)
        np.testing.assert_array_equal(_read(path, tile), patch)
        assert len(w.calls) == len(strips) + tile.rows
        w.flush()  # flush on an empty run is a no-op, not an error
        assert len(w.calls) == len(strips) + tile.rows
    finally:
        w.close()


@needs_pwrite
def test_strip_writer_flush_makes_data_visible(tmp_path):
    path = str(tmp_path / "f.rtif")
    info = ImageInfo(8, 4, 1, np.float32)
    data = np.arange(32, dtype=np.float32).reshape(8, 4, 1)
    with RecordingStripWriter(path, info) as w:
        w.write(ImageRegion((0, 0), (4, 4)), data[:4])
        w.flush()  # explicit flush lands the pending run
        np.testing.assert_array_equal(
            _read(path, ImageRegion((0, 0), (4, 4))), data[:4]
        )
        w.write(ImageRegion((4, 0), (4, 4)), data[4:])
    np.testing.assert_array_equal(_read(path), data)
