"""RTIF container + strip-parallel writer (the paper's MPI-IO analogue)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ImageRegion, ImageInfo, StripeSplitter, whole
from repro.core.process_object import GeoTransform
from repro.raster import io as rio
from repro.raster import RasterReader, ParallelRasterWriter, SyntheticScene
from repro.core import Pipeline, StreamingExecutor


def test_roundtrip(tmp_path):
    path = str(tmp_path / "img.rtif")
    info = ImageInfo(50, 40, 3, np.uint16, GeoTransform(1, 2, 6.0, -6.0))
    data = np.arange(50 * 40 * 3, dtype=np.uint16).reshape(50, 40, 3)
    rio.create(path, info)
    rio.write_strip(path, info, whole(50, 40), data)
    got = rio.read_region(path)
    np.testing.assert_array_equal(got, data)
    info2 = rio.read_info(path)
    assert (info2.rows, info2.cols, info2.bands) == (50, 40, 3)
    assert info2.geo.spacing_x == 6.0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 12), st.integers(10, 80))
def test_parallel_strip_writes_equal_serial(tmp_path_factory, n_writers, rows):
    tmp = tmp_path_factory.mktemp("pw")
    info = ImageInfo(rows, 17, 2, np.float32)
    data = np.random.default_rng(0).normal(size=(rows, 17, 2)).astype(np.float32)
    regions = StripeSplitter(n_splits=min(n_writers * 2, rows)).split(
        whole(rows, 17), info
    )
    strips = [(r, data[r.slices()]) for r in regions]
    path = str(tmp / "par.rtif")
    rio.parallel_write(path, info, strips, n_writers=n_writers)
    np.testing.assert_array_equal(rio.read_region(path), data)


def test_windowed_read(tmp_path):
    path = str(tmp_path / "img.rtif")
    info = ImageInfo(30, 20, 1, np.int32)
    data = np.arange(600, dtype=np.int32).reshape(30, 20, 1)
    rio.create(path, info)
    rio.write_strip(path, info, whole(30, 20), data)
    win = ImageRegion((5, 3), (10, 7))
    np.testing.assert_array_equal(rio.read_region(path, win), data[5:15, 3:10])


def test_reader_writer_pipeline(tmp_path):
    """Full loop: synthetic scene → parallel writer → reader → identical."""
    src_path = str(tmp_path / "src.rtif")
    p = Pipeline()
    s = p.add(SyntheticScene(40, 30, bands=2, dtype=np.float32))
    w = p.add(ParallelRasterWriter(src_path), [s])
    StreamingExecutor(p, w, StripeSplitter(n_splits=4)).run()

    # read back through a reader-based pipeline
    p2 = Pipeline()
    r = p2.add(RasterReader(src_path))
    from repro.raster import MemoryMapper

    m = p2.add(MemoryMapper(), [r])
    StreamingExecutor(p2, m, StripeSplitter(n_splits=3)).run()
    direct = np.asarray(s.generate(whole(40, 30)))
    np.testing.assert_allclose(m.result, direct, rtol=1e-6)


def test_strip_must_span_full_width(tmp_path):
    path = str(tmp_path / "img.rtif")
    info = ImageInfo(10, 10, 1, np.uint8)
    rio.create(path, info)
    with pytest.raises(ValueError):
        rio.write_strip(
            path, info, ImageRegion((0, 2), (5, 5)), np.zeros((5, 5, 1), np.uint8)
        )
