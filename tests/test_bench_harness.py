"""The benchmark harness must fail LOUDLY: a raising bench or a typo'd
section name exits non-zero instead of silently printing a shorter CSV
(the CI smoke job greps this contract)."""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(code=None, argv=(), timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    cmd = (
        [sys.executable, "-c", code]
        if code is not None
        else [sys.executable, "-m", "benchmarks.run", *argv]
    )
    return subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(REPO),
    )


def test_unknown_section_exits_nonzero_and_lists_valid_names():
    proc = _run(argv=["--only", "doesnotexist", "--quick"])
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
    assert "unknown benchmark section" in proc.stderr
    # the error must teach the fix: every valid section name is listed
    assert "valid sections" in proc.stderr
    for name in ("io", "streaming", "pipelines", "balancing", "kernels",
                 "roofline"):
        assert name in proc.stderr, (name, proc.stderr)


def test_unknown_section_suggests_close_match():
    proc = _run(argv=["--only", "streming", "--quick"])  # typo'd 'streaming'
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
    assert "did you mean 'streaming'" in proc.stderr, proc.stderr


def test_snapshot_writes_headline_metrics(tmp_path):
    """--snapshot emits the JSON perf-trajectory point: every CSV row keyed
    by NAME (section order must not matter) plus the headline plan-layer
    metrics when their rows ran."""
    import json

    snap_path = tmp_path / "BENCH_test.json"
    # sections deliberately reordered vs the SECTIONS declaration
    proc = _run(argv=["--only", "balancing", "--snapshot", str(snap_path), "--quick"])
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    snap = json.loads(snap_path.read_text())
    assert snap["sections"] == ["balancing"]
    assert "balance_static" in snap["rows"]
    csv_rows = [
        line.split(",")[0]
        for line in proc.stdout.splitlines()[1:]
        if line and not line.startswith("#")
    ]
    assert set(csv_rows) - {"name"} <= set(snap["rows"])
    # balancing alone carries no plan-layer rows -> no headline metrics, but
    # the key space is stable for trajectory tooling
    assert isinstance(snap["metrics"], dict)


def test_raising_bench_exits_nonzero():
    code = (
        "import sys\n"
        "from benchmarks import run\n"
        "run.SECTIONS['boom'] = ('benchmarks.does_not_exist',\n"
        "                        lambda mod, args: mod.run())\n"
        "sys.exit(run.main(['--only', 'boom', '--quick']))\n"
    )
    proc = _run(code=code)
    assert proc.returncode == 1, (proc.stdout, proc.stderr)
    assert "FAILED boom" in proc.stderr
    # the CSV header still prints so partial results remain parseable
    assert "name,us_per_call,derived" in proc.stdout


def test_failed_gate_still_snapshots_partial_rows(tmp_path):
    """A gated bench that fails late must not lose the rows it measured: the
    exception's ``partial_rows`` land in the CSV and the JSON snapshot, and
    the run still exits non-zero."""
    import json

    snap_path = tmp_path / "BENCH_partial.json"
    code = (
        "import sys\n"
        "from benchmarks import run\n"
        "import types\n"
        "mod = types.ModuleType('benchmarks.fake_gated')\n"
        "def bench_run():\n"
        "    rows = [('fake_measured_row', 12.5, 2.0)]\n"
        "    err = AssertionError('gate failed after measuring')\n"
        "    err.partial_rows = rows\n"
        "    raise err\n"
        "mod.run = bench_run\n"
        "sys.modules['benchmarks.fake_gated'] = mod\n"
        "run.SECTIONS['fakegated'] = ('benchmarks.fake_gated',\n"
        "                             lambda m, a: m.run())\n"
        f"sys.exit(run.main(['--only', 'fakegated', '--snapshot', {str(snap_path)!r}]))\n"
    )
    proc = _run(code=code)
    assert proc.returncode == 1, (proc.stdout, proc.stderr)
    assert "FAILED fakegated" in proc.stderr
    assert "fake_measured_row,12.5,2.0000" in proc.stdout
    snap = json.loads(snap_path.read_text())
    assert snap["rows"]["fake_measured_row"] == {
        "us_per_call": 12.5, "derived": 2.0
    }


def test_quick_balancing_smoke_emits_csv():
    proc = _run(argv=["--only", "balancing", "--quick"])
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    lines = [l for l in proc.stdout.splitlines() if l and not l.startswith("#")]
    assert lines[0] == "name,us_per_call,derived"
    assert len(lines) > 1, proc.stdout  # at least one data row
