"""Model zoo: per-arch reduced-config smoke tests (forward/train step on CPU,
shape + finiteness asserts) and decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import lm
from repro.models.inputs import synth_train_batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_train_batch(cfg, batch=2, seq=32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    gnorm = sum(
        float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads)
    ) ** 0.5
    assert np.isfinite(gnorm) and gnorm > 0
    # output shape checks via forward
    h, _ = lm.forward(params, cfg,
                      tokens=batch.get("tokens"), embeds=batch.get("embeds"))
    assert h.shape[0] == 2 and h.shape[-1] == cfg.d_model
    assert np.isfinite(np.asarray(h, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).family not in ("audio", "vlm")])
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    toks = synth_train_batch(cfg, batch=2, seq=32)["tokens"]
    _, cache = lm.prefill(params, cfg, toks[:, :-1], max_seq=toks.shape[1])
    logits_dec, cache = lm.decode_step(params, cfg, cache, toks[:, -1:])
    h, _ = lm.forward(params, cfg, tokens=toks)
    full = h[:, -1].astype(jnp.float32) @ lm.lm_head_weight(params, cfg).astype(
        jnp.float32
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(full), rtol=2e-2, atol=2e-2
    )


def test_multi_step_decode_matches_forward():
    cfg = reduced(get_config("gemma3-12b"))  # sliding window + global mix
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    toks = synth_train_batch(cfg, batch=2, seq=24)["tokens"]
    S0 = 16
    _, cache = lm.prefill(params, cfg, toks[:, :S0], max_seq=24)
    for t in range(S0, 24):
        logits, cache = lm.decode_step(params, cfg, cache, toks[:, t : t + 1])
    h, _ = lm.forward(params, cfg, tokens=toks)
    full = h[:, -1].astype(jnp.float32) @ lm.lm_head_weight(params, cfg).astype(
        jnp.float32
    )
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full), rtol=3e-2, atol=3e-2
    )


def test_ssd_chunked_matches_reference():
    from repro.models.ssm import ssd_chunked, ssd_reference

    rng = np.random.default_rng(0)
    B, S, H, P, G, N = 2, 48, 3, 8, 1, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)).astype(np.float32))
    A = jnp.asarray(rng.uniform(-1.5, -0.2, (H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(H,)).astype(np.float32))
    want = ssd_reference(x, dt, A, Bm, Cm, D)
    for chunk in (8, 16, 48):
        got = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


def test_moe_no_drop_at_high_capacity():
    from repro.models.moe import moe_layer

    rng = np.random.default_rng(3)
    T, d, E, f, k = 64, 16, 8, 32, 2
    x = jnp.asarray(rng.normal(size=(T, d)).astype(np.float32))
    router = jnp.asarray(rng.normal(size=(d, E)).astype(np.float32))
    wg = jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32) * 0.1)
    wu = jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32) * 0.1)
    wd = jnp.asarray(rng.normal(size=(E, f, d)).astype(np.float32) * 0.1)
    y, aux = moe_layer(x, router, wg, wu, wd, k=k, capacity_factor=8.0)
    assert float(aux["moe_dropped"]) == 0.0
    assert y.shape == (T, d)
    np.testing.assert_allclose(float(aux["moe_load"].sum()), 1.0, rtol=1e-5)

    # top-1 oracle: run each token through its argmax expert directly
    y1, _ = moe_layer(x, router, wg, wu, wd, k=1, capacity_factor=8.0)
    probs = jax.nn.softmax(x @ router, axis=-1)
    eid = np.asarray(jnp.argmax(probs, -1))
    import jax.nn as jnn

    for t in range(0, T, 7):
        e = int(eid[t])
        h = jnn.silu(x[t] @ wg[e]) * (x[t] @ wu[e])
        expect = h @ wd[e]
        np.testing.assert_allclose(np.asarray(y1[t]), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_overflow():
    from repro.models.moe import moe_layer, moe_capacity

    rng = np.random.default_rng(4)
    T, d, E, f = 128, 8, 4, 16
    x = jnp.asarray(np.ones((T, d)).astype(np.float32))  # all tokens identical
    router = jnp.asarray(rng.normal(size=(d, E)).astype(np.float32))
    wg = jnp.ones((E, d, f), jnp.float32) * 0.01
    wu = jnp.ones((E, d, f), jnp.float32) * 0.01
    wd = jnp.ones((E, f, d), jnp.float32) * 0.01
    # every token picks the same expert → guaranteed overflow at cf=1
    y, aux = moe_layer(x, router, wg, wu, wd, k=1, capacity_factor=1.0)
    assert float(aux["moe_dropped"]) > 0.4


def test_sliding_window_blocks_long_range():
    """A token beyond the window must not attend to position 0."""
    from repro.models.layers import naive_attention

    S, D = 16, 8
    q = jnp.zeros((1, S, 1, D))
    k = jnp.zeros((1, S, 1, D))
    v = jnp.zeros((1, S, 1, D)).at[0, 0, 0, 0].set(100.0)  # signal at pos 0
    pos = jnp.arange(S, dtype=jnp.int32)
    out_local = naive_attention(q, k, v, pos, pos, True, window=4, is_global=False)
    out_global = naive_attention(q, k, v, pos, pos, True, window=4, is_global=True)
    assert float(out_local[0, -1, 0, 0]) == 0.0  # window excludes pos 0
    assert float(out_global[0, -1, 0, 0]) > 0.0  # global still sees it


def test_nonparam_layernorm_has_no_params():
    cfg = reduced(get_config("olmo-1b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    assert "final_norm" not in params
    assert "norm1" not in params["blocks"]


def test_qwen_has_qkv_bias():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    assert "bq" in params["blocks"] and "bk" in params["blocks"]
