"""Property tests: every splitter tiles the domain exactly (paper §II.B/D)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import (
    AutoSplitter,
    ImageInfo,
    StripeSplitter,
    TileSplitter,
    VMEMTileSplitter,
    whole,
)


def assert_exact_cover(regions, full):
    cover = np.zeros((full.rows, full.cols), np.int32)
    for r in regions:
        assert full.contains(r), (r, full)
        rs, cs = r.slices()
        cover[rs, cs] += 1
    assert (cover == 1).all(), "regions must cover every pixel exactly once"


@given(st.integers(1, 80), st.integers(1, 80), st.integers(1, 12))
def test_stripe_splits_cover(rows, cols, n):
    info = ImageInfo(rows, cols, 3)
    full = whole(rows, cols)
    assert_exact_cover(StripeSplitter(n_splits=n).split(full, info), full)


@given(st.integers(1, 80), st.integers(1, 80), st.integers(1, 20), st.integers(1, 20))
def test_tile_splits_cover(rows, cols, th, tw):
    info = ImageInfo(rows, cols, 1)
    full = whole(rows, cols)
    assert_exact_cover(TileSplitter(th, tw).split(full, info), full)


@given(st.integers(1, 100), st.integers(1, 100), st.integers(64, 10_000),
       st.integers(1, 8))
def test_auto_splits_cover_and_fit(rows, cols, budget, workers):
    info = ImageInfo(rows, cols, 2, np.float32)
    full = whole(rows, cols)
    regions = AutoSplitter(budget, workers).split(full, info)
    assert_exact_cover(regions, full)
    # memory budget respected whenever a single row already fits
    if cols * info.bytes_per_pixel <= budget:
        for r in regions:
            assert r.num_pixels * info.bytes_per_pixel <= budget + cols * info.bytes_per_pixel


def test_auto_split_count_multiple_of_workers():
    info = ImageInfo(1000, 100, 1, np.float32)
    regions = AutoSplitter(40_000, n_workers=3).split(whole(1000, 100), info)
    assert len(regions) % 3 == 0


def test_vmem_tiles_aligned():
    info = ImageInfo(1000, 1000, 4, np.float32)
    regions = VMEMTileSplitter(2**20, align=128).split(whole(1000, 1000), info)
    assert_exact_cover(regions, whole(1000, 1000))
    interior = [r for r in regions if r.row1 < 1000 and r.col1 < 1000]
    assert all(r.rows % 128 == 0 and r.cols % 128 == 0 for r in interior)
