"""Property tests: every splitter tiles the domain exactly (paper §II.B/D),
and the virtual tile-grid geometry behind the 2-D SPMD executor partitions
the padded plane with zero clamping.

Property tests run under hypothesis when it is installed (CI test extras);
without it each ``_check_*`` body still runs over a seeded random sample so
the geometry contract is exercised everywhere, just with fewer examples.
"""
import numpy as np
import pytest

try:  # CI installs hypothesis via the test extras; local runs may lack it
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (
    AutoSplitter,
    ImageInfo,
    StripeSplitter,
    TileSplitter,
    VMEMTileSplitter,
    padded_tile_grid,
    virtual_tile_regions,
    whole,
)
from repro.core.splitting import (
    clamped_tile_spans,
    padded_strip_rows,
    virtual_strip_regions,
)


def assert_exact_cover(regions, full):
    cover = np.zeros((full.rows, full.cols), np.int32)
    for r in regions:
        assert full.contains(r), (r, full)
        rs, cs = r.slices()
        cover[rs, cs] += 1
    assert (cover == 1).all(), "regions must cover every pixel exactly once"


def _sample(seed, *ranges, n=25):
    """Seeded fallback sample of integer tuples, one per hypothesis range."""
    rng = np.random.default_rng(seed)
    out = [tuple(lo for lo, _ in ranges)]  # always include the all-min corner
    out += [tuple(int(rng.integers(lo, hi + 1)) for lo, hi in ranges)
            for _ in range(n - 1)]
    return out


def _property(seed, *ranges):
    """Run the check under hypothesis when present, else over a seeded
    deterministic sample (so the property is still exercised everywhere)."""

    def deco(check):
        if HAVE_HYPOTHESIS:
            strategies = [st.integers(lo, hi) for lo, hi in ranges]

            @given(*strategies)
            def wrapper(*args):
                check(*args)

            return wrapper

        @pytest.mark.parametrize("args", _sample(seed, *ranges))
        def wrapper(args):
            check(*args)

        return wrapper

    return deco


# -- classic splitters: exact cover ------------------------------------------
@_property(1, (1, 80), (1, 80), (1, 12))
def test_stripe_splits_cover(rows, cols, n):
    info = ImageInfo(rows, cols, 3)
    full = whole(rows, cols)
    assert_exact_cover(StripeSplitter(n_splits=n).split(full, info), full)


@_property(2, (1, 80), (1, 80), (1, 20), (1, 20))
def test_tile_splits_cover(rows, cols, th, tw):
    info = ImageInfo(rows, cols, 1)
    full = whole(rows, cols)
    assert_exact_cover(TileSplitter(th, tw).split(full, info), full)


@_property(3, (1, 100), (1, 100), (64, 10_000), (1, 8))
def test_auto_splits_cover_and_fit(rows, cols, budget, workers):
    info = ImageInfo(rows, cols, 2, np.float32)
    full = whole(rows, cols)
    regions = AutoSplitter(budget, workers).split(full, info)
    assert_exact_cover(regions, full)
    # memory budget respected whenever a single row already fits
    if cols * info.bytes_per_pixel <= budget:
        for r in regions:
            assert r.num_pixels * info.bytes_per_pixel <= budget + cols * info.bytes_per_pixel


def test_auto_split_count_multiple_of_workers():
    info = ImageInfo(1000, 100, 1, np.float32)
    regions = AutoSplitter(40_000, n_workers=3).split(whole(1000, 100), info)
    assert len(regions) % 3 == 0


def test_vmem_tiles_aligned():
    info = ImageInfo(1000, 1000, 4, np.float32)
    regions = VMEMTileSplitter(2**20, align=128).split(whole(1000, 1000), info)
    assert_exact_cover(regions, whole(1000, 1000))
    interior = [r for r in regions if r.row1 < 1000 and r.col1 < 1000]
    assert all(r.rows % 128 == 0 and r.cols % 128 == 0 for r in interior)


# -- virtual tile-grid geometry (SPMD 2-D contract) ---------------------------
@_property(4, (1, 90), (1, 90), (1, 9), (1, 9))
def test_padded_tile_grid_invariants(rows, cols, nr, nc):
    Hr, Wc, pr, pc = padded_tile_grid(rows, cols, nr, nc)
    assert nr * Hr == rows + pr and nc * Wc == cols + pc
    # minimal padding: Hr/Wc are the smallest uniform tile dims, so the pad
    # is strictly less than one row/col per worker along each axis
    assert 0 <= pr < nr and 0 <= pc < nc
    assert (Hr - 1) * nr < rows and (Wc - 1) * nc < cols


@_property(5, (1, 90), (1, 90), (1, 9), (1, 9))
def test_virtual_tiles_disjoint_exact_cover(rows, cols, nr, nc):
    """The nr×nc virtual tiles partition the PADDED grid exactly — no gaps,
    no overlaps, every tile the same Hr×Wc shape (ragged splits included:
    edge tiles spill past the image instead of shrinking)."""
    Hr, Wc, pr, pc = padded_tile_grid(rows, cols, nr, nc)
    tiles = virtual_tile_regions(rows, cols, nr, nc)
    assert len(tiles) == nr * nc
    assert all(t.size == (Hr, Wc) for t in tiles)
    assert_exact_cover(tiles, whole(rows + pr, cols + pc))
    # row-major ordering: tile k covers grid cell (k // nc, k % nc)
    for k, t in enumerate(tiles):
        assert (t.row0, t.col0) == ((k // nc) * Hr, (k % nc) * Wc)


@_property(6, (1, 90), (1, 90), (1, 9), (1, 9))
def test_virtual_tiles_clamp_to_image_cover(rows, cols, nr, nc):
    """Clamping each virtual tile to the image yields an exact cover of the
    image itself — the crop the executor applies after the masked SPMD run."""
    full = whole(rows, cols)
    clipped = [t.intersect(full) for t in virtual_tile_regions(rows, cols, nr, nc)]
    assert_exact_cover([t for t in clipped if t is not None and t.num_pixels], full)


@_property(7, (1, 90), (1, 90), (1, 12))
def test_virtual_tiles_nc1_matches_strip_oracle(rows, cols, n):
    """The nc=1 column of the tile grid IS the legacy strip geometry: same
    regions, same padding, and the same interior/border classification
    (a tile spills past the image exactly when its strip did)."""
    strips = virtual_strip_regions(rows, cols, n)
    tiles = virtual_tile_regions(rows, cols, n, 1)
    assert tiles == strips
    H, pad = padded_strip_rows(rows, n)
    Hr, Wc, pr, pc = padded_tile_grid(rows, cols, n, 1)
    assert (Hr, pr) == (H, pad) and (Wc, pc) == (cols, 0)
    full = whole(rows, cols)
    strip_border = [not full.contains(s) for s in strips]
    tile_border = [not full.contains(t) for t in tiles]
    assert tile_border == strip_border


@_property(8, (0, 30), (1, 60), (1, 15))
def test_clamped_tile_spans_partition(lo, extent, step):
    """clamped_tile_spans tiles [lo, hi) exactly: contiguous, in order, every
    span full-width except possibly the last."""
    hi = lo + extent
    spans = clamped_tile_spans(lo, hi, step)
    assert spans[0][0] == lo
    assert all(a + s == b for (a, s), (b, _) in zip(spans, spans[1:]))
    a, s = spans[-1]
    assert a + s == hi
    assert all(s == step for _, s in spans[:-1]) and 0 < spans[-1][1] <= step


def test_tile_geometry_rejects_nonpositive():
    for bad in [(0, 4, 1, 1), (4, 0, 1, 1), (4, 4, 0, 1), (4, 4, 1, 0)]:
        with pytest.raises(ValueError):
            padded_tile_grid(*bad)
    with pytest.raises(ValueError):
        clamped_tile_spans(0, 10, 0)


# -- auto splitters: unit coverage beyond the cover property ------------------
def test_auto_splitter_validates_args():
    with pytest.raises(ValueError):
        AutoSplitter(0)
    with pytest.raises(ValueError):
        AutoSplitter(1024, n_workers=0)


def test_auto_splitter_budget_drives_split_count():
    info = ImageInfo(120, 100, 1, np.float32)  # 400 B/row
    full = whole(120, 100)
    # 4 kB budget -> 10 rows/split -> 12 splits (already a multiple of 1)
    regions = AutoSplitter(4_000, n_workers=1).split(full, info)
    assert len(regions) == 12
    assert all(r.rows <= 10 for r in regions)
    # a loose budget still yields one split per worker
    assert len(AutoSplitter(10**9, n_workers=4).split(full, info)) == 4


def test_auto_splitter_single_row_floor():
    # budget below one row: degrade to 1-row strips, never zero-size regions
    info = ImageInfo(7, 100, 4, np.float32)  # 1600 B/row
    regions = AutoSplitter(100, n_workers=2).split(whole(7, 100), info)
    assert_exact_cover(regions, whole(7, 100))
    assert all(r.rows == 1 for r in regions)


def test_vmem_splitter_align_floor_and_budget():
    info = ImageInfo(600, 600, 4, np.float32)  # 16 B/px
    # tiny budget: side floors at `align` even though align^2 overflows it
    regions = VMEMTileSplitter(2**10, align=64).split(whole(600, 600), info)
    assert_exact_cover(regions, whole(600, 600))
    assert max(max(r.rows, r.cols) for r in regions) <= 64
    # roomy budget: interior tiles stay inside the VMEM budget
    regions = VMEMTileSplitter(2**22, align=128).split(whole(600, 600), info)
    assert_exact_cover(regions, whole(600, 600))
    interior = [r for r in regions if r.row1 < 600 and r.col1 < 600]
    assert interior and all(
        r.num_pixels * info.bytes_per_pixel <= 2**22 for r in interior
    )
