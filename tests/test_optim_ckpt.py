"""Optimizer, gradient compression, checkpoint/restore, fault tolerance."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_gradients,
    decompress_gradients,
    init_residuals,
    local_scales,
)
from repro.ckpt import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    AsyncCheckpointer,
    shrink_mesh,
)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=3e-2, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5
    )


def test_compression_error_feedback_unbiased_over_time():
    """EF int8 compression: accumulated applied updates track true gradients."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    resid = init_residuals(g_true)
    applied = jnp.zeros(64)
    for step in range(20):
        scales = local_scales(g_true, resid)
        q, resid = compress_gradients(g_true, resid, scales)
        deq = decompress_gradients(
            jax.tree.map(lambda x: x.astype(jnp.int32), q), scales, n_ranks=1
        )
        applied = applied + deq["w"]
    # mean applied update ≈ true gradient (residual is bounded)
    np.testing.assert_allclose(
        np.asarray(applied / 20), np.asarray(g_true["w"]), atol=2e-2
    )
    assert float(jnp.max(jnp.abs(resid["w"]))) < float(
        jnp.max(jnp.abs(g_true["w"]))
    )


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((5,), jnp.bfloat16)},
        "step_count": jnp.asarray(7, jnp.int32),
    }
    path = save_checkpoint(str(tmp_path), 7, state, n_writers=3)
    assert (pathlib.Path(path) / "COMMIT").exists()
    step, restored = restore_checkpoint(str(tmp_path), like=state, verify=True)
    assert step == 7
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_uncommitted_checkpoint_ignored(tmp_path):
    state = {"w": jnp.ones(3)}
    save_checkpoint(str(tmp_path), 1, state)
    # simulate torn write: step_2 exists but has no COMMIT
    broken = tmp_path / "step_00000002"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_retention(tmp_path):
    for s in range(6):
        save_checkpoint(str(tmp_path), s, {"w": jnp.ones(2) * s}, keep=3)
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert len(kept) == 3 and kept[-1] == "step_00000005"


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, {"w": jnp.full((4,), 3.0)})
    ck.wait()
    step, st = restore_checkpoint(str(tmp_path))
    assert step == 3
    np.testing.assert_array_equal(st["w"], np.full((4,), 3.0))


def test_fault_tolerant_trainer_recovers(tmp_path):
    """Inject a fault mid-run: the loop must restore the last checkpoint and
    finish all steps with exactly one recovery."""
    from repro.configs import get_config, reduced
    from repro.data import SyntheticTokens
    from repro.train.loop import LoopConfig, Trainer

    cfg = reduced(get_config("olmo-1b"))
    data = iter(SyntheticTokens(cfg.vocab_size, 32, 4))
    fired = {"done": False}

    def fault(step):
        if step == 12 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected device failure")

    tr = Trainer(
        cfg,
        LoopConfig(steps=20, ckpt_every=5, ckpt_dir=str(tmp_path / "ck"),
                   log_every=5),
        data,
        fault_hook=fault,
    )
    result = tr.run()
    assert result["final_step"] == 20
    assert result["recoveries"] == 1
    events = [m for m in result["log"] if m.get("event") == "recovery"]
    assert len(events) == 1 and events[0]["resumed_from"] == 10
    losses = [m["loss"] for m in result["log"] if "loss" in m]
    assert all(np.isfinite(l) for l in losses)


def test_elastic_restore_onto_smaller_mesh(subproc):
    out = subproc(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import lm
from repro.models.sharding import ShardingRules
from repro.ckpt import save_checkpoint, restore_checkpoint, shrink_mesh
import tempfile, pathlib

cfg = reduced(get_config("olmo-1b"))
params = lm.init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
save_checkpoint(d, 5, {"params": params})

# restore onto a 4-device mesh, then a 2-device mesh (node loss)
for n in (4, 2):
    mesh = shrink_mesh(jax.devices()[:n], prefer_model=2)
    rules = ShardingRules(mesh, cfg)
    specs = rules.param_specs(params)
    step, st = restore_checkpoint(d, like={"params": params},
                                  shardings={"params": specs})
    assert step == 5
    w = st["params"]["blocks"]["wq"]
    assert {dev.id for dev in w.sharding.device_set} <= {x.id for x in jax.devices()[:n]}
    np.testing.assert_allclose(np.asarray(w, np.float32),
                               np.asarray(params["blocks"]["wq"], np.float32))
print("ELASTIC_OK")
""",
        devices=8,
    )
    assert "ELASTIC_OK" in out


def test_shrink_mesh_shapes(subproc):
    out = subproc(
        r"""
import jax
from repro.ckpt import shrink_mesh
m = shrink_mesh(jax.devices(), prefer_model=4)
assert m.devices.shape == (2, 4), m.devices.shape
m2 = shrink_mesh(jax.devices()[:6], prefer_model=4)
assert m2.devices.shape[0] * m2.devices.shape[1] <= 6
m3 = shrink_mesh(jax.devices()[:3], prefer_model=4)
assert m3.devices.shape == (3, 1), m3.devices.shape
print("SHRINK_OK")
""",
        devices=8,
    )
    assert "SHRINK_OK" in out
