"""Training/serving behaviour: loss decreases, grad-accum equivalence,
batched generation, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data import Prefetcher, SyntheticTokens
from repro.models import lm
from repro.optim import adamw_init
from repro.serve import ServeEngine
from repro.train import build_grad_accum_train_step, build_train_step


def test_loss_decreases_tiny_lm():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    data = SyntheticTokens(cfg.vocab_size, seq_len=48, global_batch=8, seed=1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(build_train_step(cfg, lr=1e-3))
    losses = []
    for i in range(40):
        b = data.batch(i)
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_grad_accum_matches_full_batch():
    cfg = reduced(get_config("olmo-1b"))
    data = SyntheticTokens(cfg.vocab_size, seq_len=32, global_batch=8, seed=2)
    batch = data.batch(0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    full = build_train_step(cfg, lr=1e-3)
    accum = build_grad_accum_train_step(cfg, n_microbatches=4, lr=1e-3)
    p1, _, m1 = jax.jit(full)(params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(accum)(params, adamw_init(params), batch)
    # same loss (averaged) and nearly identical parameter update
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    w1 = np.asarray(p1["blocks"]["wq"], np.float32)
    w2 = np.asarray(p2["blocks"]["wq"], np.float32)
    np.testing.assert_allclose(w1, w2, rtol=2e-2, atol=2e-5)


def test_serve_engine_batched_generation():
    cfg = reduced(get_config("gemma-2b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=64)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(2, cfg.vocab_size, size=(3, 8)),
        jnp.int32,
    )
    out = eng.generate(prompts, max_new_tokens=12)
    assert out.shape == (3, 20)
    assert (np.asarray(out[:, :8]) == np.asarray(prompts)).all()
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


def test_data_pipeline_deterministic_and_sharded():
    a = SyntheticTokens(1000, 16, 8, seed=3, host_index=0, host_count=2)
    b = SyntheticTokens(1000, 16, 8, seed=3, host_index=1, host_count=2)
    a1, a2 = a.batch(5), a.batch(5)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # deterministic
    assert a.local_batch == 4
    assert not np.array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    # labels are next-token shifted
    full = SyntheticTokens(1000, 16, 2, seed=0)
    bt = full.batch(0)
    assert bt["tokens"].shape == (2, 16) and bt["labels"].shape == (2, 16)


def test_prefetcher_yields_in_order():
    src = SyntheticTokens(100, 8, 2, seed=0)
    pf = Prefetcher(iter(src), depth=2)
    got = [next(pf) for _ in range(3)]
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g["tokens"], src.batch(i)["tokens"])
    pf.close()
