"""Plan-layer Pallas fast path: flag resolution, fusion decisions, equivalence.

The contract under test (see ``Pipeline._plan_walk`` and
``ProcessObject.pallas_plan/pallas_body/pointwise_fn``):

  * ``use_pallas`` is tri-state — explicit True/False wins (True on CPU
    deterministically selects interpret mode), ``None`` defers to the
    ``REPRO_USE_PALLAS`` env var, and with neither set the backend decides;
  * a Pallas-planned node absorbs single-consumer pointwise chains above it
    (Convert, BandMath) into the kernel's ``pre_fn`` — one fused Pallas call
    per strip instead of N jnp passes — and the fusion decision is encoded in
    the plan signature (``("pallas", …, fused)``), so fused, unfused-pallas
    and jnp plans never collide in the registry;
  * refusals are structural and deterministic: no ``pointwise_fn``, multiple
    inputs, multiple consumers, persistent/origin-aware nodes, grid changes
    (Resample) and non-identity requested regions all stop the chain;
  * unfused pallas outputs match the jnp oracle bit-exactly for
    pansharpen/mean-shift and within a documented tolerance for GLCM
    (float32 quantize-boundary sensitivity; see ``TOL``); fused chains add
    ~1 ulp per folded op (FMA contraction inside the kernel vs per-op
    dispatch) and are held to a tight allclose instead.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import Pipeline, PlanCache, StreamingExecutor, StripeSplitter
from repro.core.region import ImageRegion
from repro.filters import MeanShift
from repro.filters.pointwise import BandMath, Concat, Convert
from repro.kernels import ops
from repro.raster import MemoryMapper, SyntheticScene, make_spot6_pair

#: documented per-kernel pallas-vs-jnp tolerances (None = bit-exact).
#: GLCM quantizes in float32 inside the kernel; accumulation-order and FMA
#: differences can flip a pixel across a bin boundary, shifting normalized
#: co-occurrence features by O(1/count) — hence the loose atol.  Pansharpen
#: and mean-shift run the same op sequence as the jnp reference.
TOL = {"P2": dict(rtol=1e-3, atol=1e-2), "P3": None, "P5": dict(rtol=1e-4, atol=1e-2)}


def _assert_close(name, got, want):
    tol = TOL.get(name)
    if tol is None:
        np.testing.assert_array_equal(got, want, err_msg=name)
    else:
        np.testing.assert_allclose(
            got.astype(np.float64), want.astype(np.float64), err_msg=name, **tol
        )


# -- flag resolution ---------------------------------------------------------
def test_resolve_explicit_flag_wins(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    assert ops.resolve_use_pallas(False) is False
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    assert ops.resolve_use_pallas(True) is True


@pytest.mark.parametrize("val,expect", [
    ("1", True), ("true", True), ("YES", True), ("on", True),
    ("0", False), ("false", False), ("No", False), ("off", False),
    (" 1 ", True),
])
def test_resolve_env_default(monkeypatch, val, expect):
    monkeypatch.setenv("REPRO_USE_PALLAS", val)
    assert ops.resolve_use_pallas(None) is expect


def test_resolve_env_garbage_raises(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "maybe")
    with pytest.raises(ValueError, match="REPRO_USE_PALLAS"):
        ops.resolve_use_pallas(None)


def test_resolve_unset_follows_backend(monkeypatch):
    import jax

    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    assert ops.resolve_use_pallas(None) is (jax.default_backend() == "tpu")


def test_env_var_reaches_plan_layer(subproc):
    """REPRO_USE_PALLAS=1 with use_pallas=None puts P5 on the pallas plan."""
    code = r"""
import numpy as np
from repro import pipelines as PP
from repro.core.region import ImageRegion
from repro.raster import SyntheticScene

p, m = PP.p5_meanshift(SyntheticScene(24, 16, bands=3, dtype=np.float32),
                       hs=2, n_iter=1)
desc = p.describe_pull(m, ImageRegion((0, 0), (24, 16)))
assert desc.pallas_nodes, "env var did not select the pallas plan"
print("ENV_PLAN_OK")
"""
    env = dict(os.environ)
    env["REPRO_USE_PALLAS"] = "1"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(__file__)), "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ENV_PLAN_OK" in proc.stdout


# -- fusion decisions --------------------------------------------------------
def _desc(p, m):
    info = p.info(m)
    return p.describe_pull(m, ImageRegion((0, 0), (info.rows, info.cols)))


def _chain_pipeline(use_pallas, n_chain=2):
    """SyntheticScene → Convert → BandMath → MeanShift → mapper."""
    p = Pipeline()
    s = p.add(SyntheticScene(48, 32, bands=3, dtype=np.float32, seed=3))
    up = s
    if n_chain >= 1:
        up = p.add(Convert(np.float32, in_range=(0.0, 4096.0),
                           out_range=(0.0, 255.0)), [up])
    if n_chain >= 2:
        up = p.add(BandMath(lambda x: x * 0.5 + 1.0, out_bands=3), [up])
    f = p.add(MeanShift(hs=2, hr=60.0, n_iter=2, use_pallas=use_pallas), [up])
    m = p.add(MemoryMapper(), [f])
    return p, m, f


def test_pointwise_chain_fuses():
    p, m, f = _chain_pipeline(True)
    desc = _desc(p, m)
    assert desc.pallas_nodes == (f._serial,)
    assert len(desc.fused_nodes) == 2  # Convert + BandMath folded in


def test_fusion_absent_on_jnp_plan():
    p, m, _ = _chain_pipeline(False)
    desc = _desc(p, m)
    assert desc.pallas_nodes == ()
    assert desc.fused_nodes == ()


def test_fused_and_unfused_signatures_distinct():
    sigs = set()
    for use_pallas, n_chain in [(True, 2), (True, 0), (False, 2)]:
        p, m, _ = _chain_pipeline(use_pallas, n_chain)
        sigs.add(_desc(p, m).signature)
    assert len(sigs) == 3  # fused-pallas, bare-pallas, jnp never collide


def test_multi_consumer_refuses_fusion():
    """A pointwise node feeding two consumers must not be absorbed (its other
    consumer still needs the materialized output)."""
    p = Pipeline()
    s = p.add(SyntheticScene(48, 32, bands=3, dtype=np.float32))
    c = p.add(Convert(np.float32, in_range=(0.0, 4096.0),
                      out_range=(0.0, 255.0)), [s])
    f1 = p.add(MeanShift(hs=2, hr=60.0, n_iter=1, use_pallas=True), [c])
    f2 = p.add(MeanShift(hs=2, hr=90.0, n_iter=1, use_pallas=True), [c])
    cat = p.add(Concat(2), [f1, f2])
    m = p.add(MemoryMapper(), [cat])
    desc = _desc(p, m)
    assert set(desc.pallas_nodes) == {f1._serial, f2._serial}
    assert desc.fused_nodes == ()  # Convert kept: two consumers


def test_resample_refuses_fusion():
    """P3's Resample changes the grid (and has no pointwise_fn): the fuse
    kernel plans as pallas but absorbs nothing."""
    p, m = PP.p3_pansharpening(*make_spot6_pair(24, 16), use_pallas=True)
    desc = _desc(p, m)
    assert len(desc.pallas_nodes) == 1
    assert desc.fused_nodes == ()


def test_persistent_node_refuses_fusion():
    """A persistent pass-through above the kernel must stay materialized —
    its accumulate hook observes the real region stream."""
    from repro.filters import BandStatistics

    p = Pipeline()
    s = p.add(SyntheticScene(48, 32, bands=3, dtype=np.float32))
    st = p.add(BandStatistics(bands=3), [s])
    f = p.add(MeanShift(hs=2, hr=60.0, n_iter=1, use_pallas=True), [st])
    m = p.add(MemoryMapper(), [f])
    desc = _desc(p, m)
    assert desc.pallas_nodes == (f._serial,)
    assert desc.fused_nodes == ()


# -- equivalence + registry behavior -----------------------------------------
def _run(p, m, cache=None, n_splits=4):
    cache = cache if cache is not None else PlanCache()
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=n_splits), plan_cache=cache, prefetch=0
    ).run()
    return np.array(m.result), cache


def test_fused_chain_matches_jnp():
    """Fusing the pointwise chain into the kernel contracts its mul+add
    sequences into FMAs that the per-op jnp dispatch doesn't — same math,
    ~1 ulp per op, so allclose rather than array_equal (the documented
    fused-chain tolerance)."""
    ref, _ = _run(*_chain_pipeline(False)[:2])
    out, cache = _run(*_chain_pipeline(True)[:2])
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-3)
    assert cache.stats.compiles == 1  # virtual borders: one fused signature


def test_interpret_mode_deterministic_on_cpu():
    """use_pallas=True off-TPU runs the kernels in interpret mode — same
    bits on every run."""
    a, _ = _run(*_chain_pipeline(True)[:2])
    b, _ = _run(*_chain_pipeline(True)[:2])
    np.testing.assert_array_equal(a, b)


def test_warm_registry_zero_new_lowers():
    p, m, _ = _chain_pipeline(True)
    _, cache = _run(p, m)
    lowers0, compiles0 = cache.stats.lowers, cache.stats.compiles
    _run(p, m, cache=cache)
    assert cache.stats.lowers == lowers0
    assert cache.stats.compiles == compiles0
    assert cache.stats.hits >= 4


@pytest.mark.parametrize("name", ["P2", "P3", "P5"])
def test_pallas_kernels_match_jnp_oracle(name):
    builds = {
        "P2": lambda up: PP.p2_textures(
            SyntheticScene(48, 32, bands=4, dtype=np.float32),
            use_pallas=up, radius=2, levels=4),
        "P3": lambda up: PP.p3_pansharpening(*make_spot6_pair(24, 16),
                                             use_pallas=up),
        "P5": lambda up: PP.p5_meanshift(
            SyntheticScene(48, 32, bands=4, dtype=np.float32),
            use_pallas=up, hs=2, n_iter=2),
    }
    ref, _ = _run(*builds[name](False))
    out, cache = _run(*builds[name](True))
    _assert_close(name, out, ref)
    assert cache.stats.compiles == 1  # one fused signature per striped run
