"""Prefetcher lifecycle hardening (the serving engine churns these).

The seed Prefetcher hung forever on three paths: a finished iterator left
consumers blocked on the queue, a raised iterator error vanished in the
producer thread, and there was no close() at all — a producer blocked on a
full queue leaked its thread.  These tests pin the hardened contract:
StopIteration on exhaustion, error propagation to the consumer, idempotent
exception-safe close() that never strands a blocked party, and the
non-blocking poll() the tile server drains prefetches with.
"""
import threading
import time

import pytest

from repro.data.pipeline import Prefetcher


def test_iterates_and_stops_on_exhaustion():
    pf = Prefetcher(iter(range(5)), depth=2)
    assert list(pf) == [0, 1, 2, 3, 4]
    with pytest.raises(StopIteration):
        next(pf)  # repeated next() keeps raising, never blocks
    pf.close()


def test_iterator_error_propagates_to_consumer():
    def gen():
        yield 1
        raise ValueError("source failed")

    pf = Prefetcher(gen(), depth=2)
    assert next(pf) == 1
    with pytest.raises(ValueError, match="source failed"):
        next(pf)
    pf.close()  # close after error is still clean


def test_close_is_idempotent_and_unblocks_full_queue_producer():
    produced = []

    def gen():
        for i in range(10_000):
            produced.append(i)
            yield i

    pf = Prefetcher(gen(), depth=2)
    assert next(pf) == 0
    # producer is now blocked on the full queue; close() must still join it
    pf.close(timeout=5.0)
    assert not pf.t.is_alive()
    assert len(produced) < 10_000  # it stopped early rather than draining
    pf.close()  # second close is a no-op
    with pytest.raises(StopIteration):
        next(pf)


def test_close_wakes_consumer_blocked_on_empty_queue():
    release = threading.Event()

    def gen():
        release.wait(timeout=10)
        return
        yield  # pragma: no cover — makes this a generator

    pf = Prefetcher(gen(), depth=2)
    got = []

    def consume():
        try:
            next(pf)
        except StopIteration:
            got.append("stopped")

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()  # blocked waiting for an item
    release.set()
    pf.close(timeout=5.0)
    t.join(timeout=5.0)
    assert got == ["stopped"]


def test_poll_is_nonblocking_and_preserves_items():
    slow = threading.Event()

    def gen():
        yield "a"
        slow.wait(timeout=10)
        yield "b"

    pf = Prefetcher(gen(), depth=2)
    deadline = time.monotonic() + 5
    first = None
    while first is None and time.monotonic() < deadline:
        first = pf.poll()
    assert first == "a"
    assert pf.poll() is None  # nothing ready — returns, does not block
    slow.set()
    second = None
    deadline = time.monotonic() + 5
    while second is None and time.monotonic() < deadline:
        second = pf.poll()
    assert second == "b"
    assert pf.poll() is None  # exhausted: keeps returning None
    pf.close()
    assert pf.poll() is None  # closed: still None, never raises


def test_two_consumers_both_wake_on_exhaustion():
    pf = Prefetcher(iter([1]), depth=2)
    results = []

    def consume():
        out = []
        while True:
            try:
                out.append(next(pf))
            except StopIteration:
                break
        results.append(out)

    threads = [threading.Thread(target=consume) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=5.0)
        assert not t.is_alive()
    assert len(results) == 2  # both consumers woke with StopIteration
    assert sum(results, []).count(1) == 1  # the item is delivered once
    pf.close()
