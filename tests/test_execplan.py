"""The unified ExecutionPlan layer: describe/lower split + shared registry.

Covers: describe-pass signatures are identical to the lowered plan's;
registry hits run zero lower passes (no closure rebuild); the process-wide
registry; cross-executor sharing — a pipeline streamed first is a registry
*hit* (zero new compiles, zero new lowers) for the thread pool; registry
counter consistency under concurrent races and LRU eviction.  The full
pipeline × executor equivalence matrix (streaming / pool / SPMD 2-4-8
devices vs the eager oracle) lives in tests/test_cross_executor_diff.py.
"""
import threading

import numpy as np

from repro import pipelines as PP
from repro.core import (
    ImageRegion,
    Pipeline,
    PlanCache,
    StreamingExecutor,
    StripeSplitter,
    global_plan_cache,
    run_pool,
)
from repro.filters import BandStatistics, gaussian_smoothing
from repro.raster import MemoryMapper, SyntheticScene, make_spot6_pair


def _graphs():
    p6, m6 = PP.p6_conversion(SyntheticScene(48, 32, bands=3, dtype=np.float32))
    p3, m3 = PP.p3_pansharpening(*make_spot6_pair(12, 8))
    halo = Pipeline()
    s = halo.add(SyntheticScene(60, 24, bands=2, dtype=np.float32))
    g = halo.add(gaussian_smoothing(1.0), [s])
    st = halo.add(BandStatistics(bands=2), [g])
    m = halo.add(MemoryMapper(), [st])
    return [(p6, m6), (p3, m3), (halo, m)]


# -- describe/lower split ----------------------------------------------------
def test_describe_signature_matches_compiled_plan():
    """The cheap describe pass and the full lower pass walk the same
    recursion: identical signature, reads, origins, persistent set."""
    for p, m in _graphs():
        for region in StripeSplitter(n_splits=5).split(
            p.info(m).full_region, p.info(m)
        ):
            desc = p.describe_pull(m, region)
            plan = p.compile_pull(m, region)
            assert desc.signature == plan.signature
            assert desc.origin_values == plan.origin_values
            assert desc.persistent_nodes == plan.persistent_nodes
            assert [(id(s), c, r) for s, c, r in desc.reads] == [
                (id(s), c, r) for s, c, r in plan.reads
            ]


def test_registry_hit_skips_lower_pass():
    """compiled_for runs the lower callback on misses only — a hit is
    describe-pass work plus a dict lookup, no closure tree."""
    p, m = PP.p6_conversion(SyntheticScene(40, 16, bands=2, dtype=np.float32))
    region = StripeSplitter(n_splits=4).split(p.info(m).full_region, p.info(m))[1]
    cache = PlanCache()
    calls = []

    def lower():
        calls.append(1)
        return p.lower_pull(desc)

    desc = p.describe_pull(m, region)
    e1 = cache.compiled_for(desc, lower)
    assert calls == [1] and cache.stats.lowers == 1 and cache.stats.misses == 1
    e2 = cache.compiled_for(desc, lower)
    assert e2 is e1
    assert calls == [1]  # hit: no second closure build
    assert cache.stats.hits == 1 and cache.stats.lowers == 1


def test_streaming_executor_lowers_once_per_signature():
    p, m = PP.p6_conversion(SyntheticScene(48, 32, bands=3, dtype=np.float32))
    cache = PlanCache()
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=8), plan_cache=cache, prefetch=0
    ).run()
    assert cache.stats.lowers == cache.stats.compiles == 1
    assert cache.stats.hits == 7


def test_global_plan_cache_is_process_wide():
    assert global_plan_cache() is global_plan_cache()
    assert isinstance(global_plan_cache(), PlanCache)


def test_global_plan_cache_reset_preserves_old_counters():
    """reset_global_plan_cache swaps in a fresh registry but must never zero
    history out from under callers that captured the old one: a StreamResult
    holding the pre-reset ``cache_stats`` keeps its eviction/compile counters
    (the perf-trajectory CI snapshot reads them after the run)."""
    from repro.core.execplan import reset_global_plan_cache

    baseline = reset_global_plan_cache()  # isolate from other tests
    try:
        cache = global_plan_cache()
        assert cache is not baseline and len(cache) == 0
        # drive real evictions through a tiny bounded registry shim: fill the
        # GLOBAL cache via an executor, then overflow a bounded one sharing
        # the same stats object semantics
        p, m = PP.p6_conversion(SyntheticScene(24, 16, bands=2, dtype=np.float32))
        res = StreamingExecutor(
            p, m, StripeSplitter(n_splits=4), plan_cache=cache, prefetch=0
        ).run()
        assert res.cache_stats is cache.stats
        for i in range(600):  # overflow the 512-entry LRU bound
            cache.get_or_build(("filler", i), lambda: object())
        assert cache.stats.evictions > 0
        evictions = cache.stats.evictions
        lowers = cache.stats.lowers
        old = reset_global_plan_cache()
        assert old is cache
        # the captured stats object survives the reset untouched
        assert res.cache_stats is old.stats
        assert old.stats.evictions == evictions
        assert old.stats.lowers == lowers
        fresh = global_plan_cache()
        assert fresh is not old
        assert len(fresh) == 0 and fresh.stats.evictions == 0
    finally:
        reset_global_plan_cache()


def test_read_stage_total_over_fully_virtual_regions():
    """The read stage must materialize ANY virtual describe host-side — even
    a strip lying entirely past the image (more workers than rows): it snaps
    to the nearest edge unit and replicates outward, the same values the
    SPMD executor's edge-padded global carries over its pad rows."""
    src = SyntheticScene(3, 8, bands=2, dtype=np.float32)
    p, m = PP.p6_conversion(src)
    # 3 rows over 4 workers -> H = 1: worker 3's strip [3, 4) is fully virtual
    desc = p.describe_pull(m, ImageRegion((3, 0), (1, 8)), virtual=True)
    assert desc.pad_rows == 1
    (arr,) = desc.read_sources()
    bottom = np.asarray(src.generate(ImageRegion((2, 0), (1, 8))))
    np.testing.assert_array_equal(np.asarray(arr), bottom)
    # mixed axis: rows partially in-image, bottom spill edge-replicates
    desc2 = p.describe_pull(m, ImageRegion((1, 0), (4, 8)), virtual=True)
    (arr2,) = desc2.read_sources()
    whole = np.asarray(src.generate(ImageRegion((0, 0), (3, 8))))
    expect = np.concatenate([whole[1:], whole[2:], whole[2:]], axis=0)
    np.testing.assert_array_equal(np.asarray(arr2), expect)


def test_serial_signatures_distinct_across_pipelines():
    """Two structurally identical pipelines must not share signatures (node
    serials, not recycled ids, key the process-wide registry)."""
    def mk():
        p, m = PP.p6_conversion(SyntheticScene(24, 16, bands=1, dtype=np.float32))
        return p.describe_pull(m, p.info(m).full_region).signature

    assert mk() != mk()


# -- cross-executor sharing: streaming then pool ------------------------------
def test_pool_after_streaming_is_registry_hit():
    """Second executor on the same pipeline/geometry: hits, zero new
    compiles, zero new lowers."""
    p, m = PP.p6_conversion(SyntheticScene(64, 32, bands=3, dtype=np.float32))
    oracle = np.asarray(p.pull(m, p.info(m).full_region))
    cache = PlanCache()
    splitter = StripeSplitter(n_splits=8)
    StreamingExecutor(p, m, splitter, plan_cache=cache, prefetch=0).run()
    np.testing.assert_array_equal(m.result, oracle)
    compiles0, lowers0 = cache.stats.compiles, cache.stats.lowers
    hits0 = cache.stats.hits

    res = run_pool(p, m, splitter, n_workers=3, plan_cache=cache)
    np.testing.assert_array_equal(m.result, oracle)
    assert res.cache_stats is cache.stats
    assert cache.stats.compiles == compiles0  # zero new traces
    assert cache.stats.lowers == lowers0  # zero new closure trees
    assert cache.stats.hits == hits0 + 8  # every region a hit


def test_run_pipeline_routes_through_shared_registry():
    cache = PlanCache()
    src = SyntheticScene(48, 24, bands=2, dtype=np.float32)
    res1, m1 = PP.run_pipeline(
        "P6", src, plan_cache=cache, splitter=StripeSplitter(n_splits=6)
    )
    assert res1.cache_stats is cache.stats and cache.stats.hits == 5
    res2, m2 = PP.run_pipeline(
        "P6", src, executor="pool", n_workers=2, plan_cache=cache,
        splitter=StripeSplitter(n_splits=6),
    )
    # same source object but a fresh pipeline instance → fresh signatures;
    # within the run the uniform split still hits
    np.testing.assert_array_equal(m1.result, m2.result)
    p_or, m_or = PP.p6_conversion(src)
    np.testing.assert_array_equal(
        m1.result, np.asarray(p_or.pull(m_or, p_or.info(m_or).full_region))
    )


def test_run_pipeline_prebuilt_pair_reuses_plans_across_executors():
    """Passing the built (pipeline, mapper) pair makes cross-executor reuse
    real: the pool run after the streaming run is all registry hits."""
    cache = PlanCache()
    built = PP.p6_conversion(SyntheticScene(48, 24, bands=2, dtype=np.float32))
    PP.run_pipeline(built, plan_cache=cache, splitter=StripeSplitter(n_splits=6))
    compiles0, lowers0 = cache.stats.compiles, cache.stats.lowers
    hits0 = cache.stats.hits
    res, m = PP.run_pipeline(
        built, executor="pool", n_workers=2, plan_cache=cache,
        splitter=StripeSplitter(n_splits=6),
    )
    assert cache.stats.compiles == compiles0
    assert cache.stats.lowers == lowers0
    assert cache.stats.hits == hits0 + 6
    p_or, m_or = PP.p6_conversion(
        SyntheticScene(48, 24, bands=2, dtype=np.float32)
    )
    np.testing.assert_array_equal(
        m.result, np.asarray(p_or.pull(m_or, p_or.info(m_or).full_region))
    )


# -- registry counter consistency under concurrent races ----------------------
def _spin_barrier_run(n_threads, fn):
    barrier = threading.Barrier(n_threads)
    errors = []

    def run(w):
        try:
            barrier.wait()
            fn(w)
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=run, args=(w,)) for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors


def test_plan_cache_unbounded_concurrent_races_lower_once_per_signature():
    """Racing compiled_for calls may both run the lower callback, but the
    registry counts exactly one lower/miss per signature (first insert wins)
    and every other call is a hit — no signature is lowered twice in the
    stats without an eviction in between."""
    p, m = PP.p6_conversion(SyntheticScene(40, 16, bands=2, dtype=np.float32))
    info = p.info(m)
    regions = StripeSplitter(n_splits=4).split(info.full_region, info)
    descs = [p.describe_pull(m, r) for r in regions]
    # all four stripes share one signature (uniform split, no halos)
    signatures = {d.signature for d in descs}
    cache = PlanCache()
    n_threads, reps = 8, 5

    def work(w):
        for rep in range(reps):
            d = descs[(w + rep) % len(descs)]
            entry = cache.compiled_for(d, lambda d=d: p.lower_pull(d))
            assert entry is not None

    _spin_barrier_run(n_threads, work)
    total = n_threads * reps
    s = cache.stats
    assert s.hits + s.misses == total
    assert s.misses == s.lowers == len(signatures) == len(cache)
    assert s.evictions == 0


def test_plan_cache_lru_eviction_under_concurrent_get_or_build():
    """Threaded stress over more signatures than max_entries: counters stay
    consistent (hits + misses == calls, inserts == misses, evictions ==
    inserts - live entries) and re-building an evicted key is a counted miss,
    never a silent double-build of a live entry."""
    cache = PlanCache(max_entries=4)
    n_threads, n_keys, reps = 8, 12, 40
    built = []
    built_lock = threading.Lock()

    def work(w):
        rng = np.random.default_rng(w)
        for _ in range(reps):
            key = ("prog", int(rng.integers(n_keys)))

            def build(key=key):
                with built_lock:
                    built.append(key)
                return object()

            assert cache.get_or_build(key, build) is not None

    _spin_barrier_run(n_threads, work)
    s = cache.stats
    total = n_threads * reps
    assert s.hits + s.misses == total
    assert len(cache) <= 4
    assert s.evictions == s.misses - len(cache)
    # racing builds may overshoot the counted misses, but never undershoot:
    # every counted miss ran a build
    assert s.misses <= len(built)
    assert s.evictions > 0  # the stress actually exercised LRU churn


def test_plan_cache_eviction_then_rebuild_is_counted_miss():
    p, m = PP.p6_conversion(SyntheticScene(48, 16, bands=1, dtype=np.float32))
    info = p.info(m)
    # distinct stripe heights → two distinct signatures
    r0 = StripeSplitter(n_splits=2).split(info.full_region, info)[0]
    r1 = StripeSplitter(n_splits=3).split(info.full_region, info)[0]
    assert r0.size != r1.size
    cache = PlanCache(max_entries=1)
    d0, d1 = p.describe_pull(m, r0), p.describe_pull(m, r1)
    lower_calls = []

    def lower(d):
        lower_calls.append(d.signature)
        return p.lower_pull(d)

    cache.compiled_for(d0, lambda: lower(d0))
    cache.compiled_for(d1, lambda: lower(d1))  # evicts d0's entry
    assert cache.stats.evictions == 1
    cache.compiled_for(d0, lambda: lower(d0))  # rebuild after eviction
    assert lower_calls.count(d0.signature) == 2
    assert cache.stats.lowers == 3 and cache.stats.misses == 3
    assert cache.stats.hits == 0
