"""The unified ExecutionPlan layer: describe/lower split + shared registry.

Covers: describe-pass signatures are identical to the lowered plan's;
registry hits run zero lower passes (no closure rebuild); the process-wide
registry; cross-executor sharing — a pipeline streamed first is a registry
*hit* (zero new compiles, zero new lowers) for the thread pool and for the
shard_map SPMD executor on matching strip geometry.  P1–P7 outputs agree
with the eager oracle across executors: exactly on the pool path (same
traces), and within float tolerance on the SPMD path, whose halo rows fuse
differently at image borders.
"""
import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import (
    Pipeline,
    PlanCache,
    StreamingExecutor,
    StripeSplitter,
    global_plan_cache,
    run_pool,
)
from repro.filters import BandStatistics, gaussian_smoothing
from repro.raster import MemoryMapper, SyntheticScene, make_spot6_pair


def _graphs():
    p6, m6 = PP.p6_conversion(SyntheticScene(48, 32, bands=3, dtype=np.float32))
    p3, m3 = PP.p3_pansharpening(*make_spot6_pair(12, 8))
    halo = Pipeline()
    s = halo.add(SyntheticScene(60, 24, bands=2, dtype=np.float32))
    g = halo.add(gaussian_smoothing(1.0), [s])
    st = halo.add(BandStatistics(bands=2), [g])
    m = halo.add(MemoryMapper(), [st])
    return [(p6, m6), (p3, m3), (halo, m)]


# -- describe/lower split ----------------------------------------------------
def test_describe_signature_matches_compiled_plan():
    """The cheap describe pass and the full lower pass walk the same
    recursion: identical signature, reads, origins, persistent set."""
    for p, m in _graphs():
        for region in StripeSplitter(n_splits=5).split(
            p.info(m).full_region, p.info(m)
        ):
            desc = p.describe_pull(m, region)
            plan = p.compile_pull(m, region)
            assert desc.signature == plan.signature
            assert desc.origin_values == plan.origin_values
            assert desc.persistent_nodes == plan.persistent_nodes
            assert [(id(s), c, r) for s, c, r in desc.reads] == [
                (id(s), c, r) for s, c, r in plan.reads
            ]


def test_registry_hit_skips_lower_pass():
    """compiled_for runs the lower callback on misses only — a hit is
    describe-pass work plus a dict lookup, no closure tree."""
    p, m = PP.p6_conversion(SyntheticScene(40, 16, bands=2, dtype=np.float32))
    region = StripeSplitter(n_splits=4).split(p.info(m).full_region, p.info(m))[1]
    cache = PlanCache()
    calls = []

    def lower():
        calls.append(1)
        return p.lower_pull(desc)

    desc = p.describe_pull(m, region)
    e1 = cache.compiled_for(desc, lower)
    assert calls == [1] and cache.stats.lowers == 1 and cache.stats.misses == 1
    e2 = cache.compiled_for(desc, lower)
    assert e2 is e1
    assert calls == [1]  # hit: no second closure build
    assert cache.stats.hits == 1 and cache.stats.lowers == 1


def test_streaming_executor_lowers_once_per_signature():
    p, m = PP.p6_conversion(SyntheticScene(48, 32, bands=3, dtype=np.float32))
    cache = PlanCache()
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=8), plan_cache=cache, prefetch=0
    ).run()
    assert cache.stats.lowers == cache.stats.compiles == 1
    assert cache.stats.hits == 7


def test_global_plan_cache_is_process_wide():
    assert global_plan_cache() is global_plan_cache()
    assert isinstance(global_plan_cache(), PlanCache)


def test_serial_signatures_distinct_across_pipelines():
    """Two structurally identical pipelines must not share signatures (node
    serials, not recycled ids, key the process-wide registry)."""
    def mk():
        p, m = PP.p6_conversion(SyntheticScene(24, 16, bands=1, dtype=np.float32))
        return p.describe_pull(m, p.info(m).full_region).signature

    assert mk() != mk()


# -- cross-executor sharing: streaming then pool ------------------------------
def test_pool_after_streaming_is_registry_hit():
    """Second executor on the same pipeline/geometry: hits, zero new
    compiles, zero new lowers."""
    p, m = PP.p6_conversion(SyntheticScene(64, 32, bands=3, dtype=np.float32))
    oracle = np.asarray(p.pull(m, p.info(m).full_region))
    cache = PlanCache()
    splitter = StripeSplitter(n_splits=8)
    StreamingExecutor(p, m, splitter, plan_cache=cache, prefetch=0).run()
    np.testing.assert_array_equal(m.result, oracle)
    compiles0, lowers0 = cache.stats.compiles, cache.stats.lowers
    hits0 = cache.stats.hits

    res = run_pool(p, m, splitter, n_workers=3, plan_cache=cache)
    np.testing.assert_array_equal(m.result, oracle)
    assert res.cache_stats is cache.stats
    assert cache.stats.compiles == compiles0  # zero new traces
    assert cache.stats.lowers == lowers0  # zero new closure trees
    assert cache.stats.hits == hits0 + 8  # every region a hit


def test_run_pipeline_routes_through_shared_registry():
    cache = PlanCache()
    src = SyntheticScene(48, 24, bands=2, dtype=np.float32)
    res1, m1 = PP.run_pipeline(
        "P6", src, plan_cache=cache, splitter=StripeSplitter(n_splits=6)
    )
    assert res1.cache_stats is cache.stats and cache.stats.hits == 5
    res2, m2 = PP.run_pipeline(
        "P6", src, executor="pool", n_workers=2, plan_cache=cache,
        splitter=StripeSplitter(n_splits=6),
    )
    # same source object but a fresh pipeline instance → fresh signatures;
    # within the run the uniform split still hits
    np.testing.assert_array_equal(m1.result, m2.result)
    p_or, m_or = PP.p6_conversion(src)
    np.testing.assert_array_equal(
        m1.result, np.asarray(p_or.pull(m_or, p_or.info(m_or).full_region))
    )


def test_run_pipeline_prebuilt_pair_reuses_plans_across_executors():
    """Passing the built (pipeline, mapper) pair makes cross-executor reuse
    real: the pool run after the streaming run is all registry hits."""
    cache = PlanCache()
    built = PP.p6_conversion(SyntheticScene(48, 24, bands=2, dtype=np.float32))
    PP.run_pipeline(built, plan_cache=cache, splitter=StripeSplitter(n_splits=6))
    compiles0, lowers0 = cache.stats.compiles, cache.stats.lowers
    hits0 = cache.stats.hits
    res, m = PP.run_pipeline(
        built, executor="pool", n_workers=2, plan_cache=cache,
        splitter=StripeSplitter(n_splits=6),
    )
    assert cache.stats.compiles == compiles0
    assert cache.stats.lowers == lowers0
    assert cache.stats.hits == hits0 + 6
    p_or, m_or = PP.p6_conversion(
        SyntheticScene(48, 24, bands=2, dtype=np.float32)
    )
    np.testing.assert_array_equal(
        m.result, np.asarray(p_or.pull(m_or, p_or.info(m_or).full_region))
    )


# -- cross-executor sharing: streaming then SPMD (8 virtual devices) ----------
CODE_CROSS_EXECUTOR = r"""
import numpy as np
from repro import pipelines as PP
from repro.core import PlanCache, StreamingExecutor, StripeSplitter
from repro.core.parallel import ParallelExecutor
from repro.raster import SyntheticScene, make_spot6_pair

N = 8

def src(rows=48, cols=32):
    return SyntheticScene(rows, cols, bands=4, dtype=np.float32)

CASES = {
    # P1's warp halo needs >= 12-row strips (96 rows / 8 workers)
    "P1": lambda: PP.p1_orthorectification(src(96, 64)),
    "P2": lambda: PP.p2_textures(src(), radius=2, levels=4),
    "P3": lambda: PP.p3_pansharpening(*make_spot6_pair(24, 16)),
    "P4": lambda: PP.p4_classification(src()),
    "P5": lambda: PP.p5_meanshift(src(), hs=2, n_iter=2),
    "P6": lambda: PP.p6_conversion(src()),
    "P7": lambda: PP.p7_resampling(src(32, 24)),
}

unified = {}
for name, build in CASES.items():
    p, m = build()
    info = p.info(m)
    oracle = np.asarray(p.pull(m, info.full_region)).astype(np.float64)
    cache = PlanCache()
    # matching strip geometry: 8 stripes == 8 SPMD strips
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=N), plan_cache=cache, prefetch=0
    ).run()
    streamed = np.asarray(m.result).astype(np.float64)
    np.testing.assert_allclose(streamed, oracle, rtol=1e-4, atol=1e-3,
                               err_msg=f"{name}: streaming != oracle")
    compiles0, lowers0 = cache.stats.compiles, cache.stats.lowers
    hits0 = cache.stats.hits

    pe = ParallelExecutor(p, m, plan_cache=cache)
    res = pe.run()
    spmd = np.asarray(m.result).astype(np.float64)
    np.testing.assert_allclose(spmd, oracle, rtol=1e-4, atol=1e-3,
                               err_msg=f"{name}: spmd != oracle")
    assert res.cache_stats is cache.stats, name
    unified[name] = pe.plan.unified
    if pe.plan.unified:
        # the acceptance bar: the second executor records registry HITS —
        # zero new jax traces, zero new closure trees
        assert cache.stats.compiles == compiles0, (name, cache.stats)
        assert cache.stats.lowers == lowers0, (name, cache.stats)
        assert cache.stats.hits > hits0, (name, cache.stats)

        # a second SPMD executor reuses the registered program outright
        hits1 = cache.stats.hits
        ParallelExecutor(p, m, plan_cache=cache).run()
        np.testing.assert_allclose(
            np.asarray(m.result).astype(np.float64), oracle,
            rtol=1e-4, atol=1e-3)
        assert cache.stats.compiles == compiles0, (name, cache.stats)
        assert cache.stats.lowers == lowers0, (name, cache.stats)
        assert cache.stats.hits >= hits1 + 2, (name, cache.stats)

print("UNIFIED", sorted(k for k, v in unified.items() if v))
# P1's warp needs coordinate reads (whole-shard + traced origins) → legacy;
# every covariant pipeline must share one trace with the streaming stripes
assert not unified["P1"]
for name in ("P2", "P3", "P4", "P5", "P6", "P7"):
    assert unified[name], f"{name} fell off the unified path"
print("CROSS_EXECUTOR_OK")
"""


def test_cross_executor_bit_identity_and_registry_hits(subproc):
    out = subproc(CODE_CROSS_EXECUTOR, devices=8, timeout=1800)
    assert "CROSS_EXECUTOR_OK" in out
