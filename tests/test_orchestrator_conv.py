"""Multi-pipeline orchestration (paper §IV.C future work) + convolution
filters."""
import numpy as np
import pytest

from repro.core import Orchestrator, Pipeline, Stage, StreamingExecutor, StripeSplitter
from repro.filters import (
    SobelGradient,
    gaussian_kernel,
    gaussian_smoothing,
)
from repro.raster import MemoryMapper, ParallelRasterWriter, RasterReader, SyntheticScene


def test_gaussian_kernel_normalized():
    k = gaussian_kernel(2.0)
    assert abs(k.sum() - 1.0) < 1e-6
    assert k[len(k) // 2] == k.max()


def test_convolution_streamed_equals_whole():
    def build():
        p = Pipeline()
        s = p.add(SyntheticScene(48, 40, bands=2, dtype=np.float32))
        g = p.add(gaussian_smoothing(1.2), [s])
        e = p.add(SobelGradient(), [g])
        m = p.add(MemoryMapper(), [e])
        return p, m

    p, m = build()
    whole = np.asarray(p.pull(m, p.info(m).full_region))
    p2, m2 = build()
    StreamingExecutor(p2, m2, StripeSplitter(n_splits=6)).run()
    np.testing.assert_allclose(m2.result, whole, rtol=1e-4, atol=1e-3)


def test_orchestrator_two_stage_dag(tmp_path):
    """smooth → (read product) → edges: staged execution through RTIF files
    equals the fused single-pipeline result."""
    scene = SyntheticScene(40, 32, bands=1, dtype=np.float32, seed=3)

    def stage1(_inputs, out):
        p = Pipeline()
        s = p.add(SyntheticScene(40, 32, bands=1, dtype=np.float32, seed=3))
        g = p.add(gaussian_smoothing(1.0), [s])
        m = p.add(ParallelRasterWriter(out), [g])
        return p, m

    def stage2(inputs, out):
        p = Pipeline()
        r = p.add(RasterReader(inputs["smooth"]))
        e = p.add(SobelGradient(), [r])
        m = p.add(ParallelRasterWriter(out), [e])
        return p, m

    orch = Orchestrator(
        [
            Stage("smooth", stage1, n_workers=2),
            Stage("edges", stage2, inputs=("smooth",), n_workers=3,
                  scheduler="lpt"),
        ],
        workdir=str(tmp_path),
    )
    results = orch.run()
    assert set(results) == {"smooth", "edges"}
    staged = RasterReader(results["edges"].path).read_region()

    # fused oracle
    p = Pipeline()
    s = p.add(SyntheticScene(40, 32, bands=1, dtype=np.float32, seed=3))
    g = p.add(gaussian_smoothing(1.0), [s])
    e = p.add(SobelGradient(), [g])
    m = p.add(MemoryMapper(), [e])
    fused = np.asarray(p.pull(m, p.info(m).full_region))
    np.testing.assert_allclose(staged, fused, rtol=1e-4, atol=1e-3)


def test_orchestrator_rejects_bad_dag(tmp_path):
    with pytest.raises(ValueError):
        Orchestrator([Stage("b", lambda i, o: None, inputs=("a",))],
                     workdir=str(tmp_path))

def test_orchestrator_mixed_streaming_and_spmd_stages(tmp_path):
    """A DAG mixing a thread-pool stage and a shard_map SPMD stage (one
    device here) runs against one shared plan registry and still equals the
    fused oracle; both stage results surface the registry counters."""
    from repro.core import PlanCache

    def stage1(_inputs, out):
        p = Pipeline()
        s = p.add(SyntheticScene(40, 32, bands=1, dtype=np.float32, seed=5))
        g = p.add(gaussian_smoothing(1.0), [s])
        m = p.add(ParallelRasterWriter(out), [g])
        return p, m

    def stage2(inputs, out):
        p = Pipeline()
        r = p.add(RasterReader(inputs["smooth"]))
        e = p.add(SobelGradient(), [r])
        m = p.add(ParallelRasterWriter(out), [e])
        return p, m

    cache = PlanCache()
    orch = Orchestrator(
        [
            Stage("smooth", stage1, n_workers=2, executor="pool"),
            Stage("edges", stage2, inputs=("smooth",), n_workers=1,
                  executor="spmd"),
        ],
        workdir=str(tmp_path),
        plan_cache=cache,
    )
    results = orch.run()
    assert results["smooth"].cache_stats is cache.stats
    assert results["edges"].cache_stats is cache.stats
    staged = RasterReader(results["edges"].path).read_region()

    p = Pipeline()
    s = p.add(SyntheticScene(40, 32, bands=1, dtype=np.float32, seed=5))
    g = p.add(gaussian_smoothing(1.0), [s])
    e = p.add(SobelGradient(), [g])
    m = p.add(MemoryMapper(), [e])
    fused = np.asarray(p.pull(m, p.info(m).full_region))
    np.testing.assert_allclose(staged, fused, rtol=1e-4, atol=1e-3)
