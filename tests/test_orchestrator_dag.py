"""Region-granularity DAG scheduler (pipelined orchestrator) — concurrency
test harness.

Locks in the edge-queue commit protocol of :mod:`repro.core.dag`:

  * **Property** (hypothesis): random stage-DAG topologies × queue
    capacities (1–4) × worker counts × splitters produce bit-identical
    stage outputs under the pipelined scheduler and the sequential barrier
    oracle, with zero *extra* plan-cache lowers/compiles (a fresh-cache
    pipelined run records exactly the counts of a fresh-cache barrier run —
    region-granularity streaming adds no re-tracing).
  * **Deadlock/starvation regressions**: tight queue capacity + slow
    consumer + fast producer stays inside the capacity bound; halo demand
    past the frontier overdrafts instead of cycle-waiting; a producer that
    raises mid-stream cancels its consumers with the original exception;
    cancel-while-blocked unwinds promptly.  Every potentially-wedging run
    goes through an in-test watchdog (thread + join timeout + cancel) so a
    regression FAILS even without the pytest-timeout plugin, and the
    module-level ``pytest.mark.timeout`` arms the plugin's watchdog in CI.
  * **Hygiene**: ``Orchestrator.cleanup()`` / context-manager workdir
    lifecycle, :class:`~repro.core.RowCoverage` interval algebra,
    :class:`~repro.core.EdgeQueue` unit behavior.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    EdgeQueue,
    Orchestrator,
    Pipeline,
    PipelineCancelled,
    PlanCache,
    RowCoverage,
    Stage,
    StripeSplitter,
    TileSplitter,
    UpstreamFailed,
)
from repro.core.process_object import Filter
from repro.core.region import ImageRegion
from repro.filters import BandMath, Concat, SobelGradient, gaussian_smoothing
from repro.raster import ParallelRasterWriter, RasterReader, SyntheticScene

try:  # CI installs hypothesis via the test extras; local runs may lack it
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# per-test watchdog via pytest-timeout when the plugin is installed (CI);
# the in-test watchdogs below keep the suite hang-free without it
pytestmark = pytest.mark.timeout(120)

ROWS, COLS = 24, 16


# -- helpers ------------------------------------------------------------------
def run_watchdogged(orch: Orchestrator, timeout: float = 60.0, **kw):
    """Run the orchestrator on a helper thread; a wedge FAILS the test
    (after a best-effort cancel) instead of hanging the suite."""
    box: dict = {}

    def target():
        try:
            box["result"] = orch.run(**kw)
        except BaseException as exc:  # noqa: BLE001 — re-raised on the test thread
            box["error"] = exc

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout)
    if t.is_alive():
        orch.cancel()
        t.join(10)
        pytest.fail(f"orchestrator run wedged (>{timeout}s)")
    if "error" in box:
        raise box["error"]
    return box["result"]


class _SleepFilter(Filter):
    """Identity with a fixed host-side per-region cost (``use_jit=False``
    stages only — under jit the sleep would run once, at trace time)."""

    def __init__(self, seconds: float, name=None):
        super().__init__(name)
        self.seconds = seconds

    def output_info(self, info):
        return info

    def generate(self, out_region, x):
        time.sleep(self.seconds)
        return x


class _FailAtRow(Filter):
    """Identity that raises once the region origin reaches ``fail_row``."""

    def __init__(self, fail_row: int, message: str, name=None):
        super().__init__(name)
        self.fail_row = fail_row
        self.message = message

    def output_info(self, info):
        return info

    def generate(self, out_region, x):
        if out_region.row0 >= self.fail_row:
            raise RuntimeError(self.message)
        return x


def _stage(name, inputs, mid_filters, *, n_workers=1, n_splits=4,
           use_jit=True, seed=7, rows=ROWS, cols=COLS):
    """A pool Stage: readers (Concat on fan-in) → mid filters → 2-band
    projection → commit-capable writer.  The projection keeps every stage on
    one band count so any stage can feed any other."""

    def build(input_paths, out_path):
        p = Pipeline()
        if inputs:
            ins = [p.add(RasterReader(input_paths[i])) for i in inputs]
            x = ins[0] if len(ins) == 1 else p.add(Concat(len(ins)), ins)
        else:
            x = p.add(SyntheticScene(rows, cols, bands=2, dtype=np.float32,
                                     seed=seed))
        for f in mid_filters():
            x = p.add(f, [x])
        x = p.add(BandMath(_two_bands, out_bands=2), [x])
        m = p.add(ParallelRasterWriter(out_path), [x])
        return p, m

    return Stage(name, build, inputs=tuple(inputs), n_workers=n_workers,
                 splitter=StripeSplitter(n_splits=n_splits), use_jit=use_jit)


def _two_bands(a):
    import jax.numpy as jnp

    return jnp.concatenate([a, a], axis=-1)[..., :2]


_KINDS = {
    "smooth": lambda: [gaussian_smoothing(1.0)],   # halo reads
    "sobel": lambda: [SobelGradient()],            # halo reads, 1-band mid
    "scale": lambda: [],                           # pointwise only
}


def _run_both(stages_fn, queue_capacity=2, max_workers=None, timeout=120.0):
    """Barrier oracle and pipelined run on fresh caches; returns
    (outputs_barrier, outputs_pipelined, cache_barrier, cache_pipelined,
    edge_stats)."""
    cache_b, cache_p = PlanCache(), PlanCache()
    with Orchestrator(stages_fn(), plan_cache=cache_b) as orch:
        res = run_watchdogged(orch, timeout)
        barrier = {k: RasterReader(v.path).read_region() for k, v in res.items()}
    with Orchestrator(stages_fn(), plan_cache=cache_p, pipelined=True,
                      queue_capacity=queue_capacity,
                      max_workers=max_workers) as orch:
        res = run_watchdogged(orch, timeout)
        pipelined = {k: RasterReader(v.path).read_region() for k, v in res.items()}
        stats = dict(orch.edge_stats)
    return barrier, pipelined, cache_b, cache_p, stats


# -- property: random DAGs are bit-identical to the barrier oracle ------------
def _check_dag_case(spec, capacity):
    """Any topology × capacity × workers × splits: pipelined output is
    bit-identical to the barrier oracle and adds zero extra plan-cache
    lowers/compiles (fresh-cache counts match exactly)."""

    def stages():
        return [
            _stage(f"s{i}", [f"s{j}" for j in inputs], _KINDS[kind],
                   n_workers=n_workers, n_splits=n_splits)
            for i, (inputs, kind, n_workers, n_splits) in enumerate(spec)
        ]

    barrier, pipelined, cache_b, cache_p, stats = _run_both(
        stages, queue_capacity=capacity)
    assert set(barrier) == set(pipelined)
    for name in barrier:
        np.testing.assert_array_equal(
            pipelined[name], barrier[name],
            err_msg=f"stage {name} diverged from the barrier oracle "
                    f"(spec={spec}, capacity={capacity})")
    assert cache_p.stats.lowers == cache_b.stats.lowers, (
        spec, cache_b.stats, cache_p.stats)
    assert cache_p.stats.compiles == cache_b.stats.compiles, (
        spec, cache_b.stats, cache_p.stats)
    assert all(s.offers > 0 for s in stats.values())


if HAVE_HYPOTHESIS:

    @st.composite
    def dag_specs(draw):
        n_stages = draw(st.integers(2, 4))
        spec = []
        for i in range(n_stages):
            if i == 0:
                inputs = ()
            else:
                k = draw(st.integers(1, min(2, i)))
                inputs = tuple(
                    draw(st.lists(st.sampled_from(range(i)), min_size=k,
                                  max_size=k, unique=True).map(sorted))
                )
            kind = draw(st.sampled_from(sorted(_KINDS)))
            n_workers = draw(st.integers(1, 3))
            n_splits = draw(st.integers(2, 6))
            spec.append((inputs, kind, n_workers, n_splits))
        capacity = draw(st.integers(1, 4))
        return spec, capacity

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(dag_specs())
    def test_random_dag_pipelined_equals_barrier(case):
        _check_dag_case(*case)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_dag_pipelined_equals_barrier():
        pass


def test_diamond_dag_pipelined_equals_barrier():
    """Deterministic fan-out/fan-in cover (runs even without hypothesis):
    source → {smooth, sobel} → concat sink, mixed worker counts and ragged
    splits, capacity 1."""
    _check_dag_case(
        [((), "scale", 2, 5),
         ((0,), "smooth", 1, 3),
         ((0,), "sobel", 2, 4),
         ((1, 2), "scale", 3, 6)],
        capacity=1,
    )


# -- deadlock/starvation regressions ------------------------------------------
def _chain(consumer_sleep=0.0, producer_sleep=0.0, n_splits=8, use_jit=False,
           consumer_filters=()):
    def stages():
        return [
            _stage("produce", [],
                   (lambda: [_SleepFilter(producer_sleep)])
                   if producer_sleep else (lambda: []),
                   n_splits=n_splits, use_jit=use_jit),
            _stage("consume", ["produce"],
                   lambda: list(consumer_filters)
                   + ([_SleepFilter(consumer_sleep)] if consumer_sleep else []),
                   n_splits=n_splits, use_jit=use_jit),
        ]

    return stages


def test_tight_capacity_slow_consumer_fast_producer():
    """capacity=1 + fast producer + slow consumer: the producer is paced to
    the commit frontier — at most one zero-halo strip in flight, no
    overdrafts, outputs bit-identical to the barrier oracle."""
    barrier, pipelined, _, _, stats = _run_both(
        _chain(consumer_sleep=0.02), queue_capacity=1, timeout=60.0)
    for name in barrier:
        np.testing.assert_array_equal(pipelined[name], barrier[name])
    (edge,) = stats.values()
    assert edge.max_in_flight <= 1, edge
    assert edge.overdrafts == 0, edge
    assert edge.commits > 0 and edge.releases > 0, edge


def test_halo_demand_overdrafts_instead_of_deadlocking():
    """capacity=1 + a halo consumer: region 0 needs rows past the only
    in-flight strip, which must overdraft (bounded, demand-driven) rather
    than cycle-wait — and outputs still match the oracle exactly."""
    barrier, pipelined, _, _, stats = _run_both(
        _chain(consumer_filters=(gaussian_smoothing(1.0),)),
        queue_capacity=1, timeout=60.0)
    for name in barrier:
        np.testing.assert_array_equal(pipelined[name], barrier[name])
    (edge,) = stats.values()
    assert edge.overdrafts >= 1, edge  # the halo demand forced the overdraft
    assert edge.max_in_flight <= 3, edge  # ...but stayed demand-bounded


def test_producer_failure_cancels_consumers_with_original_exception():
    """A producer that raises mid-stream must fail the whole run with ITS
    exception — consumers unwind via UpstreamFailed instead of hanging on
    rows that will never commit."""

    def stages():
        return [
            _stage("produce", [], lambda: [_FailAtRow(ROWS // 2, "boom-mid")],
                   n_splits=8, use_jit=False),
            _stage("consume", ["produce"], lambda: [_SleepFilter(0.01)],
                   n_splits=8, use_jit=False),
        ]

    with Orchestrator(stages(), pipelined=True, queue_capacity=1) as orch:
        with pytest.raises(RuntimeError, match="boom-mid"):
            run_watchdogged(orch, timeout=60.0)


def test_consumer_failure_unblocks_backpressured_producer():
    """The inverse direction: a consumer that raises must wake a producer
    blocked on backpressure (PipelineCancelled), and the run surfaces the
    consumer's original exception as the root cause."""

    def stages():
        return [
            _stage("produce", [], lambda: [_SleepFilter(0.005)],
                   n_splits=8, use_jit=False),
            _stage("consume", ["produce"],
                   lambda: [_FailAtRow(ROWS // 2, "consumer-boom")],
                   n_splits=8, use_jit=False),
        ]

    with Orchestrator(stages(), pipelined=True, queue_capacity=1) as orch:
        with pytest.raises(RuntimeError, match="consumer-boom"):
            run_watchdogged(orch, timeout=60.0)


def test_cancel_while_blocked_unwinds_promptly():
    """Orchestrator.cancel() during a pipelined run: blocked producers and
    consumers unwind with PipelineCancelled well before the run would have
    finished on its own."""
    per_region, n_splits = 0.25, 12

    def stages():
        return [
            _stage("produce", [], lambda: [_SleepFilter(per_region)],
                   n_splits=n_splits, use_jit=False, rows=48),
            _stage("consume", ["produce"], lambda: [],
                   n_splits=n_splits, use_jit=False, rows=48),
        ]

    orch = Orchestrator(stages(), pipelined=True, queue_capacity=1)
    try:
        box: dict = {}

        def target():
            try:
                orch.run()
            except BaseException as exc:  # noqa: BLE001
                box["error"] = exc

        t0 = time.perf_counter()
        t = threading.Thread(target=target, daemon=True)
        t.start()
        time.sleep(0.4)
        orch.cancel()
        t.join(20)
        elapsed = time.perf_counter() - t0
        assert not t.is_alive(), "cancelled run did not unwind"
        assert isinstance(box.get("error"), PipelineCancelled), box.get("error")
        # full run = 12 regions x 0.25s producer alone; cancel cut it short
        assert elapsed < per_region * n_splits * 0.8, elapsed
    finally:
        orch.cleanup()


def test_pipelined_rejects_tile_split_producers():
    """Row-granularity commits need full-width strips: a tiled producer is a
    loud ValueError, not silent corruption."""

    def stages():
        s = _stage("produce", [], lambda: [], use_jit=False)
        s = Stage(s.name, s.build, splitter=TileSplitter(2, 2), use_jit=False)
        return [
            s,
            _stage("consume", ["produce"], lambda: [], use_jit=False),
        ]

    with Orchestrator(stages(), pipelined=True) as orch:
        with pytest.raises(ValueError, match="full-width"):
            run_watchdogged(orch, timeout=60.0)


def test_worker_budget_shared_across_stages():
    """max_workers caps concurrently-running stage workers; the run still
    completes bit-identically (budget waits point up the DAG, no cycle)."""
    barrier, pipelined, _, _, _ = _run_both(
        _chain(consumer_sleep=0.005), queue_capacity=2, max_workers=2,
        timeout=60.0)
    for name in barrier:
        np.testing.assert_array_equal(pipelined[name], barrier[name])


# -- workdir lifecycle --------------------------------------------------------
def _single_stage():
    return [_stage("only", [], lambda: [], use_jit=False)]


def test_cleanup_removes_owned_workdir():
    orch = Orchestrator(_single_stage())
    assert orch.workdir.exists()
    orch.run()
    orch.cleanup()
    assert not orch.workdir.exists()
    orch.cleanup()  # idempotent


def test_context_manager_removes_owned_workdir():
    with Orchestrator(_single_stage()) as orch:
        wd = orch.workdir
        orch.run()
        assert wd.exists()
    assert not wd.exists()


def test_cleanup_keeps_caller_supplied_workdir(tmp_path):
    with Orchestrator(_single_stage(), workdir=str(tmp_path)) as orch:
        orch.run()
    assert tmp_path.exists()  # caller-owned: left alone


# -- validation ---------------------------------------------------------------
def test_orchestrator_validates_pipelining_args():
    with pytest.raises(ValueError, match="queue_capacity"):
        Orchestrator(_single_stage(), queue_capacity=0)
    with pytest.raises(ValueError, match="max_workers"):
        Orchestrator(_single_stage(), max_workers=0)


def test_upstream_failed_unwraps_to_root_cause():
    root = ValueError("root")
    nested = UpstreamFailed("b", UpstreamFailed("a", root))
    assert nested.stage == "a"
    assert nested.cause is root


# -- EdgeQueue units ----------------------------------------------------------
def test_edge_queue_rejects_tile_offers_and_bad_capacity():
    with pytest.raises(ValueError, match="capacity"):
        EdgeQueue("p", "c", capacity=0)
    q = EdgeQueue("p", "c", capacity=1)
    with pytest.raises(ValueError, match="full-width"):
        q.offer(ImageRegion((0, 4), (4, 4)))


def test_edge_queue_wait_rows_detects_missing_commit_hook():
    q = EdgeQueue("p", "c", capacity=1)
    q.open(8)
    q.close_producer()  # producer "done" without ever committing rows
    # close_producer marks all rows committed (normal completion)...
    q.wait_rows(0, 8)
    # ...but a producer that dies before open+close leaves waiters failing
    q2 = EdgeQueue("p", "c", capacity=1)
    q2.open(8)
    q2.fail("p", RuntimeError("dead"))
    with pytest.raises(UpstreamFailed) as ei:
        q2.wait_rows(0, 4)
    assert ei.value.stage == "p"
    assert "dead" in repr(ei.value.cause)


def test_edge_queue_cancel_wakes_blocked_consumer():
    q = EdgeQueue("p", "c", capacity=1)
    q.open(8)
    box: dict = {}

    def waiter():
        try:
            q.wait_rows(0, 8)
        except BaseException as exc:  # noqa: BLE001
            box["error"] = exc

    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.05)
    q.cancel(PipelineCancelled("stop"))
    t.join(10)
    assert not t.is_alive()
    assert isinstance(box.get("error"), PipelineCancelled)


def test_edge_queue_commit_coverage_gates_waits():
    q = EdgeQueue("p", "c", capacity=4)
    q.open(16)
    q.consumer_started()
    q.commit(0, 8)
    q.wait_rows(0, 8)  # returns immediately: covered
    q.commit(8, 16)
    q.wait_rows(4, 12)  # spans both committed runs
    assert q.stats.commits == 2


# -- RowCoverage algebra ------------------------------------------------------
def test_row_coverage_matches_set_oracle():
    """Randomized out-of-order interval commits (seeded, no hypothesis
    needed) match a set-of-rows oracle, and the interval list stays sorted,
    disjoint and non-adjacent."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        cov = RowCoverage()
        model: set = set()
        for _ in range(rng.integers(0, 20)):
            lo, hi = int(rng.integers(0, 40)), int(rng.integers(0, 40))
            cov.add(lo, hi)
            model.update(range(lo, hi))
        assert cov.covered_rows() == len(model)
        for _ in range(10):
            lo, hi = int(rng.integers(0, 40)), int(rng.integers(0, 40))
            expected = hi <= lo or all(r in model for r in range(lo, hi))
            assert cov.covers(lo, hi) == expected, (cov.intervals(), lo, hi)
        ivals = cov.intervals()
        assert all(a < b for a, b in ivals)
        assert all(
            ivals[i][1] < ivals[i + 1][0] for i in range(len(ivals) - 1)
        )
