"""The compiled-plan cache + async double-buffered streaming engine.

Covers the three engine layers: canonical plans share one compiled function
per (shape, boundary, plan-key) signature; prefetch/write-behind is
bit-identical to the synchronous loop; persistent filters run through the
compiled path with state bit-identical to the eager oracle; and the
work-stealing pool drains every region exactly once.
"""
import threading

import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import (
    Filter,
    Pipeline,
    PlanCache,
    StreamingExecutor,
    StripeSplitter,
    TileSplitter,
    WorkStealingQueue,
    execute,
    run_pool,
)
from repro.filters import BandStatistics, gaussian_smoothing
from repro.raster import MemoryMapper, SyntheticScene, make_spot6_pair


def _src(rows=48, cols=32, bands=3):
    return SyntheticScene(rows, cols, bands=bands, dtype=np.float32)


def _stats_pipeline(rows=40, cols=30):
    p = Pipeline()
    s = p.add(SyntheticScene(rows, cols, bands=3, dtype=np.float32))
    st = p.add(BandStatistics(bands=3), [s])
    m = p.add(MemoryMapper(), [st])
    return p, m


# -- layer 1+2: canonical plans + PlanCache ---------------------------------
def test_uniform_stripes_compile_exactly_once():
    """A halo-free pipeline over uniform stripes: one trace, N−1 hits."""
    p, m = PP.p6_conversion(_src(48, 32))
    cache = PlanCache()
    res = StreamingExecutor(
        p, m, StripeSplitter(n_splits=8), plan_cache=cache, prefetch=0
    ).run()
    assert res.cache_stats is cache.stats
    assert cache.stats.compiles == 1
    assert cache.stats.misses == 1
    assert cache.stats.hits == 7


def test_halo_pipeline_compiles_once_despite_boundaries():
    """Halo pipelines describe against the virtual padded geometry, so border
    stripes (whose halo reads spill past the image rows) land on the interior
    signature: ONE compile for the whole striped run, the spill materialized
    by edge replication at the read stage."""
    p = Pipeline()
    s = p.add(_src(60, 24))
    g = p.add(gaussian_smoothing(1.0), [s])
    m = p.add(MemoryMapper(), [g])
    cache = PlanCache()
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=10), plan_cache=cache, prefetch=0
    ).run()
    assert cache.stats.compiles == 1
    assert cache.stats.hits == 9


def test_stacked_stencils_keep_exact_border_describes():
    """A halo landing on a row-stencil INTERMEDIATE (gauss → sobel) refuses
    virtual describes: the eager oracle edge-replicates the gaussian's output
    rows at the image border, which virtual geometry (replicating only raw
    source rows) cannot reproduce.  The run then pays per-border signatures
    but stays bit-compatible with the whole-image pull.  Halos that reach a
    source — directly or through row-transparent pointwise filters — keep
    the one-signature virtual path."""
    from repro.filters import BandMath, MeanShift, SobelGradient

    p = Pipeline()
    s = p.add(_src(48, 40))
    g = p.add(gaussian_smoothing(1.2), [s])
    e = p.add(SobelGradient(), [g])
    m = p.add(MemoryMapper(), [e])
    assert not p.virtual_rows_safe()
    assert not StreamingExecutor(p, m).describe_virtual
    cache = PlanCache()
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=6), plan_cache=cache, prefetch=0
    ).run()
    assert cache.stats.compiles == 3  # top / interior / bottom

    # single stencil onto a source: virtual stays on
    p2 = Pipeline()
    s2 = p2.add(_src(48, 40))
    g2 = p2.add(gaussian_smoothing(1.2), [s2])
    m2 = p2.add(MemoryMapper(), [g2])
    assert p2.virtual_rows_safe()

    # stencil onto a row-transparent pointwise run onto a source: still safe
    p3 = Pipeline()
    s3 = p3.add(_src(48, 40))
    b3 = p3.add(BandMath(lambda x: x * 0.5 + 1.0, out_bands=3), [s3])
    f3 = p3.add(MeanShift(hs=2, hr=60.0, n_iter=1), [b3])
    m3 = p3.add(MemoryMapper(), [f3])
    assert p3.virtual_rows_safe()


def test_plan_cache_shared_across_executors():
    """Worker ranks sharing one cache compile once between them."""
    cache = PlanCache()
    for w in range(3):
        p, m = PP.p6_conversion(_src(48, 32))
        StreamingExecutor(
            p, m, StripeSplitter(n_splits=6), worker=w, n_workers=3,
            plan_cache=cache, prefetch=0,
        ).run()
    # node ids differ per pipeline instance, so each rank's pipeline gets its
    # own entry — but within a rank all uniform stripes share one
    assert cache.stats.compiles == 3


def test_plan_cache_lru_eviction():
    p, m = PP.p6_conversion(_src(10, 16))
    cache = PlanCache(max_entries=1)
    # 10 rows / 4 splits → three 3-row stripes + one 1-row stripe
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=4), plan_cache=cache, prefetch=0
    ).run()
    assert cache.stats.compiles == 2
    assert cache.stats.evictions == 1
    assert len(cache) == 1


def test_rejit_baseline_never_caches():
    """cache=False keeps the seed's per-region re-jit semantics reachable."""
    p, m = PP.p6_conversion(_src(48, 32))
    cache = PlanCache()
    res = StreamingExecutor(
        p, m, StripeSplitter(n_splits=4), plan_cache=cache, cache=False
    ).run()
    assert res.cache_stats is None
    assert cache.stats.compiles == 0
    p2, m2 = PP.p6_conversion(_src(48, 32))
    whole = np.asarray(p2.pull(m2, p2.info(m2).full_region))
    np.testing.assert_array_equal(m.result, whole)


def test_p3_registered_in_pipeline_registry():
    assert PP.ALL["P3"] is PP.p3_pansharpening
    assert set(PP.ALL) >= {"P1", "P2", "P3", "P4", "P5", "P6", "P7", "IO"}


# -- layer 3: async double buffering ----------------------------------------
P17_CASES = {
    "P1": lambda: PP.p1_orthorectification(_src(40, 32, bands=4)),
    "P2": lambda: PP.p2_textures(_src(40, 32, bands=4)),
    "P3": lambda: PP.p3_pansharpening(*make_spot6_pair(10, 8)),
    "P4": lambda: PP.p4_classification(_src(40, 32, bands=4)),
    "P5": lambda: PP.p5_meanshift(_src(40, 32, bands=4), hs=2, n_iter=2),
    "P6": lambda: PP.p6_conversion(_src(40, 32, bands=4)),
    "P7": lambda: PP.p7_resampling(_src(20, 16, bands=4)),
}


@pytest.mark.parametrize("name", list(P17_CASES))
def test_prefetch_bit_identical_to_sync(name):
    """Overlapping reads/writes must not change a single bit of output."""
    build = P17_CASES[name]
    p1, m1 = build()
    sync = StreamingExecutor(p1, m1, StripeSplitter(n_splits=5), prefetch=0).run()
    p2, m2 = build()
    asyn = StreamingExecutor(p2, m2, StripeSplitter(n_splits=5), prefetch=3).run()
    np.testing.assert_array_equal(m1.result, m2.result)
    assert sync.regions_processed == asyn.regions_processed
    assert sync.pixels_processed == asyn.pixels_processed


def test_prefetch_keep_outputs_ordered():
    p, m = PP.p6_conversion(_src(48, 32))
    res = execute(p, m, StripeSplitter(n_splits=6), keep_outputs=True, prefetch=2)
    assert res.outputs is not None and len(res.outputs) == 6
    np.testing.assert_array_equal(np.concatenate(res.outputs, axis=0), m.result)


def test_execute_separates_ctor_and_run_kwargs():
    p, m = PP.p6_conversion(_src(24, 16))
    res = execute(p, m, keep_outputs=True, prefetch=0, scheduler="lpt")
    assert res.outputs is not None
    assert res.regions_processed == len(res.outputs)


# -- persistent filters through the compiled path ---------------------------
def test_persistent_compiled_state_bit_identical_to_eager():
    p1, m1 = _stats_pipeline()
    compiled = StreamingExecutor(p1, m1, StripeSplitter(n_splits=7), prefetch=2).run()
    p2, m2 = _stats_pipeline()
    eager = StreamingExecutor(p2, m2, StripeSplitter(n_splits=7), use_jit=False).run()
    assert compiled.cache_stats is not None  # really took the compiled path
    assert compiled.cache_stats.compiles >= 1
    a = compiled.persistent_results["BandStatistics"]
    b = eager.persistent_results["BandStatistics"]
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), err_msg=k)
    np.testing.assert_array_equal(m1.result, m2.result)


def test_persistent_compiled_tiles_match_global_stats():
    p, m = _stats_pipeline(36, 30)
    res = StreamingExecutor(p, m, TileSplitter(10, 13), prefetch=2).run()
    img = np.asarray(m.result)
    stats = res.persistent_results["BandStatistics"]
    np.testing.assert_allclose(
        np.asarray(stats["mean"]), img.reshape(-1, 3).mean(0), rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(stats["max"]), img.reshape(-1, 3).max(0), rtol=1e-5
    )


def test_region_dependent_persistent_filter_via_plan_key():
    """accumulate()'s region argument is canonical (shape-only) under the
    compiled path; a filter whose state depends on absolute coordinates must
    override plan_key — then compiled matches eager exactly."""
    import jax.numpy as jnp

    from repro.core import PersistentFilter, Reduction

    class RowWeighted(PersistentFilter):
        state_reductions = {"acc": Reduction("sum")}

        def plan_key(self, out_region):
            return out_region.index  # absolute coords enter the trace

        def reset(self):
            return {"acc": jnp.zeros((), jnp.float32)}

        def accumulate(self, st, region, x, mask=None):
            return {"acc": st["acc"] + region.row0 * x.sum()}

    def mk():
        p = Pipeline()
        s = p.add(SyntheticScene(32, 16, bands=1, dtype=np.float32))
        f = p.add(RowWeighted(), [s])
        m = p.add(MemoryMapper(), [f])
        return p, m

    p1, m1 = mk()
    compiled = StreamingExecutor(p1, m1, StripeSplitter(n_splits=8)).run()
    p2, m2 = mk()
    eager = StreamingExecutor(p2, m2, StripeSplitter(n_splits=8), use_jit=False).run()
    np.testing.assert_array_equal(
        np.asarray(compiled.persistent_results["RowWeighted"]["acc"]),
        np.asarray(eager.persistent_results["RowWeighted"]["acc"]),
    )
    # the plan_key forces one compile per distinct origin
    assert compiled.cache_stats.compiles == 8


def test_mapper_end_called_on_error():
    """A failing region must not leak the writer: end() runs on the error
    path (releasing StripWriter descriptors) before the exception surfaces."""
    from repro.core.process_object import Mapper

    class Boom(Mapper):
        def __init__(self):
            super().__init__()
            self.ended = 0

        def consume(self, region, data):
            raise RuntimeError("boom")

        def end(self):
            self.ended += 1

    p = Pipeline()
    s = p.add(_src(24, 16))
    m = p.add(Boom(), [s])
    with pytest.raises(RuntimeError):
        StreamingExecutor(p, m, StripeSplitter(n_splits=4), prefetch=2).run()
    assert m.ended == 1
    p = Pipeline()
    s = p.add(_src(24, 16))
    m = p.add(Boom(), [s])
    with pytest.raises(RuntimeError):
        run_pool(p, m, StripeSplitter(n_splits=4), n_workers=2)
    assert m.ended == 1


# -- the work-stealing pool --------------------------------------------------
def test_work_stealing_queue_drains_exactly_once_concurrently():
    q = WorkStealingQueue(200, 4, costs=list(np.linspace(1, 3, 200)))
    taken = [[] for _ in range(4)]

    def drain(w):
        while True:
            i = q.take(w)
            if i is None:
                return
            taken[w].append(i)

    threads = [threading.Thread(target=drain, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    flat = sorted(i for lst in taken for i in lst)
    assert flat == list(range(200))


def test_work_stealing_queue_steals_half_from_most_loaded():
    q = WorkStealingQueue(8, 2, costs=[10, 10, 10, 10, 1, 1, 1, 1])
    # worker 1 drains its own cheap half, then must steal worker 0's tail
    for _ in range(4):
        assert q.take(1) in (4, 5, 6, 7)
    # steal-half: one steal operation transfers the tail block [2, 3] in
    # original order — 2 comes back, 3 lands in the thief's deque
    stolen = q.take(1)
    assert stolen == 2
    assert q.steals == 1
    assert q.items_stolen == 2
    assert q.take(1) == 3  # from the thief's own deque, no second steal
    assert q.steals == 1


def test_work_stealing_steal_half_bounds_lock_traffic():
    """A lone thief draining a loaded victim: steal-half needs O(log n) steal
    operations (each a lock acquisition on the shared queue) where steal-one
    needed n — the contention bound that matters on very fine splits."""
    n = 64
    q = WorkStealingQueue(n, 2)  # worker 0 owns [0, 32), worker 1 owns [32, 64)
    taken = []
    while True:
        i = q.take(1)  # worker 1 does all the work; worker 0 never shows up
        if i is None:
            break
        taken.append(i)
    assert sorted(taken) == list(range(n))  # drained exactly once
    # 32 own items cost zero steals; the other 32 arrive in halving blocks:
    # 16, 8, 4, 2, 1, 1 → 6 steal operations, not 32
    assert q.steals <= 7
    assert q.items_stolen == 32


def test_run_pool_matches_oracle_and_compiles_once():
    p, m = PP.p6_conversion(_src(64, 32))
    res = run_pool(
        p, m, StripeSplitter(n_splits=16), n_workers=4, scheduler="work_stealing"
    )
    assert res.regions_processed == 16
    assert res.cache_stats.compiles == 1  # shared cache across all workers
    p2, m2 = PP.p6_conversion(_src(64, 32))
    whole = np.asarray(p2.pull(m2, p2.info(m2).full_region))
    np.testing.assert_array_equal(m.result, whole)


@pytest.mark.parametrize("scheduler", ["static", "lpt", "work_stealing"])
def test_run_pool_persistent_stats_any_scheduler(scheduler):
    p, m = _stats_pipeline(48, 30)
    res = run_pool(
        p, m, StripeSplitter(n_splits=12), n_workers=3, scheduler=scheduler
    )
    img = np.asarray(m.result)
    stats = res.persistent_results["BandStatistics"]
    # combine order differs per worker split → same tolerance as the seed's
    # split-invariance property test
    np.testing.assert_allclose(
        np.asarray(stats["mean"]), img.reshape(-1, 3).mean(0), rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(stats["max"]), img.reshape(-1, 3).max(0), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(stats["std"]), img.reshape(-1, 3).std(0), rtol=1e-3, atol=1e-3
    )


def test_raster_writer_tile_split(tmp_path):
    """StripWriter's windowed pwrite path: tile splits (not full-width) land
    every pixel in its final in-file position."""
    from repro.raster import ParallelRasterWriter, RasterReader

    path = str(tmp_path / "tiles.rtif")
    p, m = PP.p6_conversion(
        _src(40, 28), mapper_factory=lambda: ParallelRasterWriter(path)
    )
    run_pool(p, m, TileSplitter(16, 12), n_workers=3, scheduler="work_stealing")
    p2, m2 = PP.p6_conversion(_src(40, 28))
    whole = np.asarray(p2.pull(m2, p2.info(m2).full_region))
    np.testing.assert_array_equal(RasterReader(path).read_region(), whole)


def test_run_pool_eager_path():
    p, m = _stats_pipeline(30, 20)
    res = run_pool(
        p, m, StripeSplitter(n_splits=6), n_workers=2, use_jit=False
    )
    assert res.cache_stats is None
    img = np.asarray(m.result)
    stats = res.persistent_results["BandStatistics"]
    np.testing.assert_allclose(
        np.asarray(stats["mean"]), img.reshape(-1, 3).mean(0), rtol=1e-4
    )
