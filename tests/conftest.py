import os
import subprocess
import sys
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Run python code in a subprocess with N virtual devices.

    Multi-device tests must not set --xla_force_host_platform_device_count in
    this process (smoke tests and benches see 1 device per the spec), so the
    flag lives only in the child environment.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=str(REPO),
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
