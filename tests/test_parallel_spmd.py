"""shard_map cluster execution on 8 virtual devices (subprocess-isolated so
the main test process keeps 1 device): parallel == streamed oracle for the
paper's pipelines, halo exchange + persistent collectives included."""


CODE_CORE = r"""
import numpy as np, jax.numpy as jnp
from repro.core import Pipeline, Filter, ParallelExecutor
from repro.raster import SyntheticScene, MemoryMapper
from repro.filters import BandStatistics

class BoxMean(Filter):
    def __init__(self, radius):
        super().__init__(); self.radius = radius
    def requested_region(self, out_region, *infos):
        return (out_region.pad(self.radius),)
    def generate(self, out_region, x):
        r = self.radius; k = 2*r+1
        c = jnp.cumsum(x, axis=0)
        c = jnp.concatenate([c[k-1:k], c[k:] - c[:-k]], axis=0)
        c2 = jnp.cumsum(c, axis=1)
        c2 = jnp.concatenate([c2[:, k-1:k], c2[:, k:] - c2[:, :-k]], axis=1)
        return c2 / (k*k)

def build():
    p = Pipeline()
    s = p.add(SyntheticScene(100, 60, bands=2, dtype=np.float32))
    f = p.add(BoxMean(2), [s])
    st = p.add(BandStatistics(bands=2), [f])
    m = p.add(MemoryMapper(), [st])
    return p, m

p, m = build()
whole = np.asarray(p.pull(m, p.info(m).full_region))
p2, m2 = build()
pe = ParallelExecutor(p2, m2)
res = pe.run()
assert res.regions_processed == 8
# 100 rows over 8 workers: 13-row VIRTUAL padded strips (4 pad rows) on the
# unified registry path, persistent state masked in-trace — no legacy closure
assert pe.plan.unified and (pe.plan.strip_rows, pe.plan.pad_rows) == (13, 4)
np.testing.assert_allclose(m2.result, whole, rtol=1e-5, atol=1e-4)
stats = res.persistent_results["BandStatistics"]
np.testing.assert_allclose(np.asarray(stats["mean"]),
                           whole.reshape(-1, 2).mean(0), rtol=1e-4)
print("CORE_OK")
"""


CODE_PIPELINES = r"""
import numpy as np
from repro import pipelines as PP
from repro.core import ParallelExecutor
from repro.raster import SyntheticScene, make_spot6_pair

def check(build, atol=1e-3):
    p, m = build()
    whole = np.asarray(p.pull(m, p.info(m).full_region)).astype(np.float64)
    p2, m2 = build()
    ParallelExecutor(p2, m2).run()
    np.testing.assert_allclose(m2.result.astype(np.float64), whole,
                               rtol=1e-4, atol=atol)

src = lambda: SyntheticScene(96, 64, bands=4, dtype=np.float32)
check(lambda: PP.p1_orthorectification(src()))          # warp + col drift
check(lambda: PP.p3_pansharpening(*make_spot6_pair(24, 16)))  # multi-res pitch
check(lambda: PP.p7_resampling(SyntheticScene(32, 24, bands=2, dtype=np.float32)))
check(lambda: PP.p6_conversion(src()), atol=1)
print("PIPELINES_OK")
"""


CODE_HALO = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map as _m; shard_map = _m.shard_map
except Exception:
    from jax.experimental.shard_map import shard_map
from repro.core.parallel import halo_exchange_rows

n = 8
mesh = Mesh(np.array(jax.devices()), ("w",))
x = jnp.arange(8 * 4 * 3, dtype=jnp.float32).reshape(32, 3)

def f(xs):
    return halo_exchange_rows(xs, 2, 1, "w", n)

y = shard_map(f, mesh=mesh, in_specs=P("w", None), out_specs=P("w", None))(x)
y = np.asarray(y).reshape(n, 4 + 3, 3)
full = np.asarray(x).reshape(n, 4, 3)
for i in range(n):
    top = full[i - 1][-2:] if i > 0 else np.repeat(full[0][:1], 2, 0)
    bot = full[i + 1][:1] if i < n - 1 else full[-1][-1:]
    expect = np.concatenate([top, full[i], bot], 0)
    np.testing.assert_array_equal(y[i], expect)
print("HALO_OK")
"""


def test_parallel_core_8dev(subproc):
    out = subproc(CODE_CORE, devices=8)
    assert "CORE_OK" in out


def test_parallel_pipelines_8dev(subproc):
    out = subproc(CODE_PIPELINES, devices=8, timeout=1200)
    assert "PIPELINES_OK" in out


def test_halo_exchange_semantics(subproc):
    out = subproc(CODE_HALO, devices=8)
    assert "HALO_OK" in out
