"""Plan-warm tile serving: grid geometry, admission control, signature
batching, and the warm-up protocol.

The load-bearing claims: (1) a batched (vmap) signature group is
**bit-identical** to per-tile streaming pulls — serving never changes
pixels; (2) after ``TileServer.warm`` the first live request performs zero
new lowers and zero new compiles (pure registry hits); (3) admission bounds
in-flight depth under a storm, shedding instead of queueing; (4) the
process-wide plan registry survives a serving-shaped concurrency storm
(many describe+hit threads racing a slow lower on another signature)
without duplicate compiles or deadlock.
"""
import threading
import time
from concurrent.futures import wait

import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import (
    BatchedRegionPuller,
    ImageRegion,
    PlanCache,
    global_plan_cache,
)
from repro.raster import ArraySource, DecimatedSource, SyntheticScene
from repro.serve import AdmissionController, Shed, TileGrid, TileRequest, TileServer


# -- tile grid geometry ------------------------------------------------------
def test_tile_grid_regions_and_ragged_edges():
    g = TileGrid(rows=50, cols=70, tile_rows=16, tile_cols=32)
    assert (g.nx, g.ny) == (3, 4)
    assert g.region(0, 0) == ImageRegion((0, 0), (16, 32))
    # ragged last row/col clamp to the image
    assert g.region(2, 3) == ImageRegion((48, 64), (2, 6))
    assert sum(1 for _ in g.tiles()) == 12
    with pytest.raises(KeyError):
        g.region(3, 0)
    with pytest.raises(ValueError):
        TileGrid(0, 10, 4, 4)


def test_tile_grid_neighbors():
    g = TileGrid(rows=64, cols=64, tile_rows=16, tile_cols=16)
    assert set(g.neighbors(0, 0)) == {(0, 1), (1, 0), (1, 1)}
    assert len(g.neighbors(1, 1)) == 8
    assert (2, 2) not in g.neighbors(0, 0)


# -- decimated (zoom) sources ------------------------------------------------
def test_decimated_source_is_strided_view():
    rng = np.random.default_rng(0)
    base = ArraySource(rng.normal(size=(37, 29, 3)).astype(np.float32))
    dec = DecimatedSource(base, 4)
    info = dec.output_info()
    assert (info.rows, info.cols) == (10, 8)  # ceil(37/4), ceil(29/4)
    full = np.asarray(dec.generate(info.full_region))
    expect = np.asarray(base.array)[::4, ::4]
    np.testing.assert_array_equal(full, expect)
    # windowed read matches the same window of the full strided view,
    # including the ragged last tile
    win = ImageRegion((8, 4), (2, 4))
    np.testing.assert_array_equal(
        np.asarray(dec.generate(win)), expect[8:10, 4:8]
    )


def test_decimated_synthetic_scene_region_independent():
    base = SyntheticScene(64, 48, bands=2, dtype=np.float32)
    dec = DecimatedSource(base, 2)
    info = dec.output_info()
    full = np.asarray(dec.generate(info.full_region))
    tile = np.asarray(dec.generate(ImageRegion((8, 8), (16, 16))))
    np.testing.assert_array_equal(tile, full[8:24, 8:24])


# -- admission control -------------------------------------------------------
def test_admission_shed_policy_bounds_depth():
    ctl = AdmissionController(max_depth=2, policy="shed")
    assert ctl.try_admit() and ctl.try_admit()
    assert not ctl.try_admit()
    with pytest.raises(Shed):
        ctl.admit()
    ctl.release()
    assert ctl.try_admit()
    snap = ctl.snapshot()
    assert snap["admitted"] == 3 and snap["shed"] == 2
    assert snap["depth"] == 2 and snap["high_water"] == 2


def test_admission_block_policy_waits_for_release():
    ctl = AdmissionController(max_depth=1, policy="block", max_wait_s=5.0)
    ctl.admit()
    got = []

    def waiter():
        got.append(ctl.try_admit())

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert not got  # still blocked behind the full depth
    ctl.release()
    t.join(timeout=5)
    assert got == [True]
    # bounded wait: a second blocked admit times out into a shed
    assert not ctl.try_admit(timeout=0.05)
    assert ctl.snapshot()["shed"] == 1


def test_admission_release_must_pair_and_held_releases_on_error():
    ctl = AdmissionController(max_depth=4)
    with pytest.raises(RuntimeError):
        ctl.release()
    with pytest.raises(ValueError):
        with ctl.held():
            assert ctl.snapshot()["depth"] == 1
            raise ValueError("boom")
    assert ctl.snapshot()["depth"] == 0


# -- serving correctness: batched == per-tile streaming pulls ----------------
def _small_server(**kw):
    kw.setdefault("rows_xs", 32)
    kw.setdefault("cols_xs", 32)
    kw.setdefault("zooms", (0, 1))
    kw.setdefault("plan_cache", PlanCache())
    kw.setdefault("tile_cache_entries", 0)
    kw.setdefault("prefetch_neighbors", False)
    kw.setdefault("batch_sizes", (1, 4))
    return PP.build_tile_server(**kw)


def _all_requests(server):
    return [
        TileRequest(name, z, x, y)
        for name, z in server.entries()
        for x, y in server._entries[(name, z)].grid.tiles()
    ]


def test_batched_tiles_bit_identical_to_per_tile_pulls():
    """Every registered tile of P2/P3/P5 across two zooms, served through
    signature-batched vmap programs, must equal the unbatched per-tile pull
    bit for bit."""
    server = _small_server()
    reqs = _all_requests(server)
    tiles = server.serve(reqs)
    assert {r.pipeline for r in reqs} == {"P2", "P3", "P5"}
    for req, tile in zip(reqs, tiles):
        entry = server._entries[(req.pipeline, req.zoom)]
        region = entry.grid.region(req.x, req.y)
        oracle = entry.puller.pull_one(region)
        assert tile.shape == (region.rows, region.cols, tile.shape[-1])
        np.testing.assert_array_equal(np.asarray(tile), np.asarray(oracle))


def test_warm_then_first_requests_are_pure_registry_hits():
    server = _small_server(zooms=(0,))
    warm = server.warm()
    assert warm and all(w["signatures"] >= 1 for w in warm.values())
    before = server.plan_cache.stats_snapshot()
    server.serve(_all_requests(server))
    after = server.plan_cache.stats_snapshot()
    assert after["lowers"] == before["lowers"]
    assert after["compiles"] == before["compiles"]
    assert after["hits"] > before["hits"]


def test_serve_unknown_entry_and_bad_tile():
    server = _small_server(zooms=(0,), pipelines=("P2",))
    with pytest.raises(KeyError):
        server.serve_one(TileRequest("P9", 0, 0, 0))
    with pytest.raises(KeyError):
        server.serve_one(TileRequest("P2", 0, 99, 0))


def test_register_rejects_duplicates_and_persistent_pipelines():
    server = _small_server(zooms=(0,), pipelines=("P2",))
    scene = SyntheticScene(32, 32, bands=4)
    p, m = PP.p2_textures(scene)
    with pytest.raises(ValueError):
        server.register("P2", 0, p, m, 16)
    from repro.core import Pipeline
    from repro.filters import BandStatistics

    pp = Pipeline()
    s = pp.add(SyntheticScene(32, 32, bands=2, dtype=np.float32))
    st = pp.add(BandStatistics(bands=2), [s])
    from repro.raster import MemoryMapper

    mm = pp.add(MemoryMapper(), [st])
    with pytest.raises(ValueError):
        server.register("stats", 0, pp, mm, 16)


# -- the request engine: futures, batching, shed under storm -----------------
def test_submit_engine_batches_and_completes():
    server = _small_server(zooms=(0,), pipelines=("P2",))
    server.warm()
    with server:
        futs = [server.submit(r) for r in _all_requests(server)]
        done, not_done = wait(futs, timeout=60)
    assert not not_done
    for f in done:
        assert f.result().ndim == 3
    m = server.metrics()
    assert sum(k * v for k, v in m["batch_histogram"].items()) == len(futs)
    assert m["admission"]["depth"] == 0
    assert m["admission"]["admitted"] == m["admission"]["completed"]


def test_submit_sheds_beyond_admission_depth():
    server = _small_server(
        zooms=(0,),
        pipelines=("P5",),
        admission=AdmissionController(max_depth=2, policy="shed"),
        max_batch=2,
    )
    server.warm()
    reqs = _all_requests(server) * 8
    with server:
        futs = [server.submit(r) for r in reqs]
        wait(futs, timeout=60)
    shed = sum(1 for f in futs if isinstance(f.exception(), Shed))
    ok = sum(1 for f in futs if f.exception() is None)
    assert ok >= 2  # at least one batch got through
    assert shed >= 1  # the storm overran depth 2
    snap = server.admission.snapshot()
    assert snap["depth"] == 0 and snap["shed"] == shed
    assert snap["admitted"] == snap["completed"] == ok


def test_submit_requires_started_server_and_stop_is_idempotent():
    server = _small_server(zooms=(0,), pipelines=("P2",))
    with pytest.raises(RuntimeError):
        server.submit(TileRequest("P2", 0, 0, 0))
    server.start()
    with pytest.raises(RuntimeError):
        server.start()
    server.stop()
    server.stop()


def test_tile_cache_hit_skips_admission_and_prefetch_fills_neighbors():
    server = _small_server(
        zooms=(0,),
        pipelines=("P2",),
        tile_cache_entries=64,
        prefetch_neighbors=True,
    )
    server.warm()
    with server:  # neighbor prefetchers only run on a started server
        first = server.serve_one(TileRequest("P2", 0, 0, 0))
        admitted = server.admission.snapshot()["admitted"]
        again = server.serve_one(TileRequest("P2", 0, 0, 0))
        np.testing.assert_array_equal(np.asarray(first), np.asarray(again))
        assert server.admission.snapshot()["admitted"] == admitted  # cache hit
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            server._drain_prefetched()
            if server.metrics()["prefetch"]["stored"] >= 1:
                break
            time.sleep(0.02)
    m = server.metrics()
    assert m["prefetch"]["enqueued"] >= 1
    assert m["prefetch"]["stored"] >= 1
    # a prefetched neighbor equals its served pull
    entry = server._entries[("P2", 0)]
    nreq = TileRequest("P2", 0, 1, 1)
    cached = server.tile_cache.get(nreq)
    if cached is not None:
        oracle = entry.puller.pull_one(entry.grid.region(1, 1))
        np.testing.assert_array_equal(np.asarray(cached), np.asarray(oracle))


# -- BatchedRegionPuller unit behavior ---------------------------------------
def test_batched_puller_bucket_rounding_and_oversize_chunking():
    scene = SyntheticScene(64, 16, bands=2, dtype=np.float32)
    p, m = PP.p6_conversion(scene)
    puller = BatchedRegionPuller(p, m, plan_cache=PlanCache(), batch_sizes=(1, 4))
    assert puller.bucket(1) == 1 and puller.bucket(3) == 4 and puller.bucket(4) == 4
    assert puller.bucket(9) == 4  # above the largest bucket: chunked by it
    regions = [ImageRegion((8 * i, 0), (8, 16)) for i in range(6)]
    tiles = puller.pull_many(regions)
    assert len(tiles) == 6
    for region, tile in zip(regions, tiles):
        np.testing.assert_array_equal(
            np.asarray(tile), np.asarray(puller.pull_one(region))
        )


def test_batched_puller_preserves_input_order_across_signatures():
    scene = SyntheticScene(50, 16, bands=2, dtype=np.float32)
    p, m = PP.p6_conversion(scene)
    puller = BatchedRegionPuller(p, m, plan_cache=PlanCache(), batch_sizes=(1, 4))
    # alternate two signature classes (10-row and 5-row tiles)
    regions = []
    for i in range(4):
        regions.append(ImageRegion((10 * i, 0), (10, 16)))
        regions.append(ImageRegion((40 + 5 * (i % 2), 0), (5, 16)))
    tiles = puller.pull_many(regions)
    for region, tile in zip(regions, tiles):
        assert tile.shape[0] == region.rows
        np.testing.assert_array_equal(
            np.asarray(tile), np.asarray(puller.pull_one(region))
        )


# -- the registry under a serving-shaped concurrency storm -------------------
def test_global_plan_cache_concurrent_serving_storm():
    """The serving workload shape on the process-wide registry: 16 threads
    describe + registry-hit + execute one warmed signature while another
    signature is being lowered slowly on a separate thread.  Exactly one
    counted lower per signature, exactly one XLA trace per signature (the
    entry priming lock), counters consistent, nobody deadlocks."""
    from repro.core.execplan import reset_global_plan_cache

    reset_global_plan_cache()
    try:
        cache = global_plan_cache()
        scene = SyntheticScene(64, 32, bands=2, dtype=np.float32)
        p, m = PP.p6_conversion(scene)
        desc_a = p.describe_pull(m, ImageRegion((0, 0), (16, 32)))
        desc_b = p.describe_pull(m, ImageRegion((32, 0), (8, 32)))
        assert desc_a.signature != desc_b.signature
        lower_calls = {"a": 0, "b": 0}
        lock = threading.Lock()
        barrier = threading.Barrier(17)
        errors = []

        def lower_a():
            with lock:
                lower_calls["a"] += 1
            return p.lower_pull(desc_a)

        def lower_b():
            with lock:
                lower_calls["b"] += 1
            time.sleep(0.2)  # a deliberately slow lower in flight
            return p.lower_pull(desc_b)

        # warm signature A the way TileServer.warm does: one lower, no trace
        # yet — the storm threads then race the FIRST execution too, which
        # the entry's priming lock must collapse to a single XLA trace.
        cache.compiled_for(desc_a, lower_a)

        def storm():
            try:
                barrier.wait(timeout=30)
                for i in range(40):
                    d = p.describe_pull(m, ImageRegion((16 * (i % 2), 0), (16, 32)))
                    entry = cache.compiled_for(d, lower_a)
                    if i % 10 == 0:  # exercise the compiled fn concurrently
                        entry(d.read_sources(), d.initial_pstates(), d.origins())
                    cache.stats_snapshot()
            except Exception as e:  # pragma: no cover — surfaced below
                errors.append(e)

        def slow_lowerer():
            try:
                barrier.wait(timeout=30)
                entry = cache.compiled_for(desc_b, lower_b)
                entry(desc_b.read_sources(), desc_b.initial_pstates(), desc_b.origins())
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=storm) for _ in range(16)]
        threads.append(threading.Thread(target=slow_lowerer))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive(), "registry deadlocked under serving storm"
        assert not errors
        assert lower_calls == {"a": 1, "b": 1}  # hits never re-lower
        snap = cache.stats_snapshot()
        assert snap["lowers"] == 2 and snap["misses"] == 2
        assert snap["hits"] == 16 * 40  # every storm lookup was a pure hit
        assert snap["compiles"] == 2  # one XLA trace per signature, no dupes
    finally:
        reset_global_plan_cache()
