"""Property tests: region algebra invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import ImageRegion, whole

regions = st.builds(
    ImageRegion,
    st.tuples(st.integers(-50, 50), st.integers(-50, 50)),
    st.tuples(st.integers(0, 60), st.integers(0, 60)),
)


@given(regions, regions)
def test_intersect_commutative(a, b):
    assert a.intersect(b) == b.intersect(a)


@given(regions, regions)
def test_intersect_contained(a, b):
    c = a.intersect(b)
    if not c.is_empty():
        assert a.contains(c) and b.contains(c)


@given(regions, st.integers(0, 8), st.integers(0, 8))
def test_pad_clamp_roundtrip(r, pr, pc):
    if r.is_empty():
        return
    padded = r.pad(pr, pc)
    assert padded.contains(r)
    assert padded.clamp(r) == r  # clamping back to the original recovers it


@given(regions, regions)
def test_union_bbox_contains_both(a, b):
    u = a.union_bbox(b)
    assert u.contains(a) and u.contains(b)


@given(regions)
def test_relative_roundtrip(r):
    outer = r.pad(3)
    rel = r.relative_to(outer)
    assert rel.shift(outer.row0, outer.col0) == r


@given(st.integers(1, 40), st.integers(1, 40))
def test_whole_slices(rows, cols):
    r = whole(rows, cols)
    arr = np.zeros((rows, cols))
    rs, cs = r.slices()
    assert arr[rs, cs].shape == (rows, cols)
