"""Windowed reads: the plan layer's static-shape window specs for drifting
``needs_origin`` (warp) requests.

Unit coverage: describe-pass classification (wread records, windows field,
signature stability across regions and at image borders), window_request
geometry (containment, in-image column shift, bound violation), and the
single-trace property — a striped P1 run lowers/compiles exactly once.

Property coverage (hypothesis): random warp displacement fields and stripe
splits — the windowed-read plan matches ``bicubic_sample`` applied to the
full image, and two different decompositions agree bit-for-bit.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pipelines as PP
from repro.core import (
    ImageInfo,
    ImageRegion,
    PlanCache,
    StreamingExecutor,
    StripeSplitter,
    TileSplitter,
)
from repro.core.process_object import window_request
from repro.filters import Orthorectify, SensorModel, bicubic_sample
from repro.raster import SyntheticScene


def _p1(rows=96, cols=64, model=None, seed=0):
    src = SyntheticScene(rows, cols, bands=2, dtype=np.float32, seed=seed)
    return PP.p1_orthorectification(src, model=model)


# -- window classification (describe pass) ------------------------------------
def test_describe_classifies_warp_read_as_window():
    p, m = _p1()
    info = p.info(m)
    region = StripeSplitter(n_splits=8).split(info.full_region, info)[3]
    desc = p.describe_pull(m, region)
    assert len(desc.reads) == 1 and len(desc.windows) == 1
    assert desc.windows[0] is not None
    _, clamped, req = desc.reads[0]
    assert req.size == desc.windows[0]  # the read IS the static window
    assert any(rec[0] == "wread" for rec in desc.signature)
    # the window origin is threaded as traced scalars, not baked in
    assert (req.row0, req.col0) == (
        desc.origin_values[2], desc.origin_values[3])


def test_window_signature_stable_across_stripes_and_borders():
    """Every stripe of a uniform split shares ONE signature — including the
    border stripes, whose window spill is materialized at the read stage
    (host boundary_pad / SPMD halo replication), not in the trace."""
    p, m = _p1()
    info = p.info(m)
    regions = StripeSplitter(n_splits=8).split(info.full_region, info)
    sigs = {p.describe_pull(m, r).signature for r in regions}
    assert len(sigs) == 1
    # windows of equal-size output regions share the bound, drift in origin
    descs = [p.describe_pull(m, r) for r in regions]
    sizes = {d.reads[0][2].size for d in descs}
    assert len(sizes) == 1
    origins = [d.reads[0][2].row0 for d in descs]
    assert origins == sorted(origins) and len(set(origins)) == len(origins)


def test_windowed_stripe_run_lowers_and_compiles_once():
    p, m = _p1()
    cache = PlanCache()
    StreamingExecutor(
        p, m, StripeSplitter(n_splits=8), plan_cache=cache, prefetch=0
    ).run()
    assert cache.stats.lowers == 1 and cache.stats.compiles == 1
    assert cache.stats.hits == 7


def test_window_bound_is_conservative_for_p1_requests():
    p, m = _p1()
    ortho = next(n for n in p.nodes if isinstance(n, Orthorectify))
    info = p.info(p.sources()[0])
    for region in (
        ImageRegion((0, 0), (12, 64)),
        ImageRegion((37, 5), (12, 64)),
        ImageRegion((84, 0), (12, 64)),
        ImageRegion((13, 17), (7, 11)),
    ):
        (req,) = ortho.requested_region(region, info)
        ((brows, bcols),) = ortho.window_bound(region.size, info)
        assert req.rows <= brows and req.cols <= bcols, (region, req)


def test_window_request_geometry():
    info = ImageInfo(100, 50, 1, np.float32)
    # interior: anchored at the request origin, exact static shape
    w = window_request(ImageRegion((10, 5), (8, 9)), (12, 14), info)
    assert w == ImageRegion((10, 5), (12, 14))
    # column shift keeps the window in-image (rows stay anchored)
    w = window_request(ImageRegion((10, 45), (8, 9)), (12, 14), info)
    assert w == ImageRegion((10, 36), (12, 14))
    w = window_request(ImageRegion((10, -6), (8, 9)), (12, 14), info)
    assert w == ImageRegion((10, 0), (12, 14))
    # window wider than the image: anchored at col 0 (uniform right pad)
    w = window_request(ImageRegion((10, -6), (8, 9)), (12, 60), info)
    assert w == ImageRegion((10, 0), (12, 60))
    # a lying bound (smaller than the request) must fail loudly
    with pytest.raises(ValueError):
        window_request(ImageRegion((0, 0), (20, 9)), (12, 14), info)


def test_windowed_plan_contains_exact_request():
    """The clamped window must contain the clamped exact request — otherwise
    the filter would sample pixels the read never materialized."""
    p, m = _p1()
    info = p.info(m)
    src_info = p.info(p.sources()[0])
    ortho = next(n for n in p.nodes if isinstance(n, Orthorectify))
    for splitter in (StripeSplitter(n_splits=8), TileSplitter(13, 17)):
        for region in splitter.split(info.full_region, info):
            desc = p.describe_pull(m, region)
            _, clamped, window = desc.reads[0]
            (exact,) = ortho.requested_region(region, src_info)
            assert clamped.contains(
                exact.clamp(src_info.full_region)
            ), (region, exact, window)


def test_uneven_rows_take_the_virtual_padded_strip_path():
    """A warp whose rows don't divide over the workers used to raise
    NotStripParallelizable (the clamped last strip had its own window
    bound); virtual padded strips describe the ragged last strip against
    the row-padded geometry, so every strip shares the interior signature
    and the plan stays on the unified registry path."""
    from repro.core.parallel import build_strip_plan
    from repro.core.splitting import padded_strip_rows, virtual_strip_regions

    p, m = _p1(rows=97)  # 97 rows over 4 workers → 25-row strips + 3 pad rows
    plan = build_strip_plan(p, m, 4)
    assert plan.unified
    assert (plan.strip_rows, plan.pad_rows) == (25, 3)
    assert padded_strip_rows(97, 4) == (25, 3)
    # all four VIRTUAL strip describes share ONE interior signature — the
    # ragged last strip included (its pad rows are read-stage material)
    descs = [
        p.describe_pull(m, r, virtual=True)
        for r in virtual_strip_regions(97, 64, 4)
    ]
    assert len({d.signature for d in descs}) == 1
    assert plan.plan_signature == descs[0].signature
    assert descs[-1].pad_rows == 3 and descs[0].pad_rows == 0
    # whereas the REAL describe of the clamped last strip stands apart
    real_last = p.describe_pull(m, ImageRegion((75, 0), (22, 64)))
    assert real_last.signature != descs[0].signature


def test_virtual_describe_matches_real_on_interior_regions():
    """On geometry that never touches a border, virtual and real describes
    are indistinguishable — same signature, same reads, same origins — so
    streaming (real) and SPMD (virtual) land on one registry entry."""
    p, m = _p1()
    region = ImageRegion((24, 0), (12, 64))
    real = p.describe_pull(m, region)
    virt = p.describe_pull(m, region, virtual=True)
    assert real.signature == virt.signature
    assert real.origin_values == virt.origin_values
    assert [r[1:] for r in real.reads] == [r[1:] for r in virt.reads]
    assert virt.pad_rows == 0 and not real.virtual and virt.virtual


def test_cross_decomposition_bit_identity():
    """Stripes, tiles and prefetch depths all reassemble the identical image
    bit-for-bit: absolute-coordinate sampling + static window shapes leave
    nothing decomposition-dependent in the trace."""
    p, m = _p1()
    StreamingExecutor(p, m, StripeSplitter(n_splits=8), prefetch=0).run()
    ref = np.array(m.result)
    StreamingExecutor(p, m, StripeSplitter(n_splits=5), prefetch=2).run()
    np.testing.assert_array_equal(m.result, ref)
    StreamingExecutor(p, m, TileSplitter(13, 17), prefetch=0).run()
    np.testing.assert_array_equal(m.result, ref)
    StreamingExecutor(p, m, StripeSplitter(n_splits=1), prefetch=0).run()
    np.testing.assert_array_equal(m.result, ref)


# -- property: random warp fields vs full-image bicubic -----------------------
def _full_image_warp_reference(src, model, rows, cols):
    full = np.asarray(src.generate(ImageRegion((0, 0), (rows, cols))))
    rr = jnp.arange(rows, dtype=jnp.float32)[:, None]
    cc = jnp.arange(cols, dtype=jnp.float32)[None, :]
    ar, ac = model.affine(rr, cc)
    dr, dc = model.displacement(rr, cc)
    return np.asarray(
        bicubic_sample(jnp.asarray(full, jnp.float32), ar + dr, ac + dc)
    )


try:  # property tests are hypothesis-gated; the unit tests above always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        rows=st.integers(24, 64),
        cols=st.integers(16, 48),
        a_rr=st.floats(0.85, 1.15),
        a_rc=st.floats(-0.05, 0.05),
        a_cr=st.floats(-0.05, 0.05),
        a_cc=st.floats(0.85, 1.15),
        b_r=st.floats(-4.0, 4.0),
        b_c=st.floats(-4.0, 4.0),
        disp_amp=st.floats(0.0, 3.0),
        disp_wavelength=st.floats(40.0, 900.0),
        n_splits=st.integers(1, 7),
        seed=st.integers(0, 4),
    )
    def test_windowed_plan_matches_full_image_bicubic(
        rows, cols, a_rr, a_rc, a_cr, a_cc, b_r, b_c, disp_amp,
        disp_wavelength, n_splits, seed,
    ):
        model = SensorModel(
            a_rr=a_rr, a_rc=a_rc, a_cr=a_cr, a_cc=a_cc, b_r=b_r, b_c=b_c,
            disp_amp=disp_amp, disp_wavelength=disp_wavelength,
        )
        src = SyntheticScene(rows, cols, bands=2, dtype=np.float32, seed=seed)
        p, m = PP.p1_orthorectification(src, model=model)
        cache = PlanCache()
        StreamingExecutor(
            p, m, StripeSplitter(n_splits=n_splits), plan_cache=cache,
            prefetch=0,
        ).run()
        out = np.array(m.result)
        ref = _full_image_warp_reference(src, model, rows, cols)
        # the only FP wiggle vs the eager reference is XLA's mul+add → FMA
        # contraction under jit (~1 ulp in the sample coordinates)
        np.testing.assert_allclose(
            out.astype(np.float64), ref.astype(np.float64),
            rtol=1e-4, atol=1e-3,
        )
        # a second, different stripe split is bit-identical to the first
        StreamingExecutor(
            p, m, StripeSplitter(n_splits=min(n_splits + 2, rows)),
            plan_cache=cache, prefetch=0,
        ).run()
        np.testing.assert_array_equal(m.result, out)

else:  # keep the skip visible in the report

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_windowed_plan_matches_full_image_bicubic():
        pass
