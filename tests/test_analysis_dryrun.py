"""Roofline analysis plumbing + a miniature dry-run on an 8-device mesh."""
import numpy as np
import pytest

from repro.launch.analysis import parse_collective_bytes, roofline_terms
from repro.launch.mesh import HW


def test_parse_collective_bytes_synthetic_hlo():
    hlo = """
  %ag = bf16[4,1024]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[128]{0} all-reduce(%y), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z)
  %cp = (f32[32]{0}, f32[32]{0}) collective-permute(%w)
  %a2a = bf16[8,16]{1,0} all-to-all(%v)
  %ags = bf16[2,8]{1,0} all-gather-start(%q)
  %not_a_collective = f32[999]{0} add(%a, %b)
"""
    got = parse_collective_bytes(hlo)
    assert got["all-gather"] == 4 * 1024 * 2 + 2 * 8 * 2
    assert got["all-reduce"] == 128 * 4
    assert got["reduce-scatter"] == 64 * 4
    assert got["collective-permute"] == 32 * 4 * 2
    assert got["all-to-all"] == 8 * 16 * 2
    assert got["total"] == sum(
        got[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9, HW)  # 1 second each by design
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 1.0)
    np.testing.assert_allclose(t["collective_s"], 1.0)
    assert t["roofline_fraction"] == 1.0
    t2 = roofline_terms(197e12, 819e9 * 3, 0.0, HW)
    assert t2["dominant"] == "memory_s"
    assert t2["roofline_fraction"] == pytest.approx(1 / 3)


def test_mini_dryrun_8dev(subproc):
    """Reduced config on a (4, 2) mesh: lower+compile, analyze, verify the
    loop-corrected FLOPs exceed the single-body count."""
    out = subproc(
        r"""
import jax, numpy as np
import dataclasses
from repro.configs import get_config, reduced, ShapeConfig
from repro.models import lm
from repro.models.sharding import ShardingRules, set_batch_axes
from repro.models.inputs import train_input_specs
from repro.optim import adamw_init
from repro.train import build_train_step
from repro.launch.analysis import analyze_compiled

cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")), n_layers=4)
shape = ShapeConfig("tiny", 64, 8, "train")
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = ShardingRules(mesh, cfg)
set_batch_axes(rules.dp_axes, rules.tp)
params_sds = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
pspecs = rules.param_specs(params_sds)
opt_sds = jax.eval_shape(lambda: adamw_init(params_sds))
from repro.optim.adamw import AdamWState
ospecs = AdamWState(step=rules.replicated(), mu=pspecs, nu=jax.tree.map(lambda s: s, pspecs))
batch_sds = train_input_specs(cfg, shape)
bspecs = rules.batch_specs(batch_sds)
step = build_train_step(cfg)
with mesh:
    fn = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                 out_shardings=(pspecs, ospecs, None), donate_argnums=(0, 1))
    compiled = fn.lower(params_sds, opt_sds, batch_sds).compile()
stats = analyze_compiled(compiled, 8)
assert stats["cost"]["flops"] > 0
assert stats["memory"]["argument_bytes"] > 0
assert stats["collectives"]["total"] > 0  # FSDP gathers must exist

# unrolled variant counts more flops than the scanned body-once variant
cfg_u = dataclasses.replace(cfg, scan_unroll=64)
with mesh:
    fn2 = jax.jit(step := build_train_step(cfg_u),
                  in_shardings=(pspecs, ospecs, bspecs),
                  out_shardings=(pspecs, ospecs, None))
    c2 = fn2.lower(params_sds, opt_sds, batch_sds).compile()
s2 = analyze_compiled(c2, 8)
assert s2["cost"]["flops"] > stats["cost"]["flops"] * 1.5
print("MINIDRY_OK")
""",
        devices=8,
        timeout=900,
    )
    assert "MINIDRY_OK" in out


def test_production_mesh_shapes(subproc):
    out = subproc(
        r"""
from repro.launch.mesh import make_production_mesh
m = make_production_mesh()
assert m.devices.shape == (16, 16) and m.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16)
assert m2.axis_names == ("pod", "data", "model")
print("MESH_OK")
""",
        devices=512,
    )
    assert "MESH_OK" in out
