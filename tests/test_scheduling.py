"""Load-balancing schedules: partition correctness + balance quality."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import (
    ImageRegion,
    cost_weighted_static_schedule,
    lpt_schedule,
    makespan,
    static_schedule,
    work_stealing_schedule,
)


def _regions(n):
    return [ImageRegion((i * 10, 0), (10, 100)) for i in range(n)]


@given(st.integers(1, 40), st.integers(1, 8))
def test_static_partitions_all(n, w):
    sched = static_schedule(_regions(n), w)
    flat = sorted(i for lst in sched for i in lst)
    assert flat == list(range(n))
    # contiguity (required by the strip-adjacent parallel writer)
    for lst in sched:
        assert lst == list(range(lst[0], lst[0] + len(lst))) if lst else True


@given(st.integers(1, 40), st.integers(1, 8), st.integers(0, 10**6))
def test_lpt_partitions_all(n, w, seed):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10.0, size=n)
    sched = lpt_schedule(_regions(n), w, lambda r: costs[r.row0 // 10])
    flat = sorted(i for lst in sched for i in lst)
    assert flat == list(range(n))


def test_lpt_beats_static_on_skewed_costs():
    """The paper's P5 (meanshift) motivates this: non-constant per-region cost
    (§IV.C).  LPT must win on a pathological skew."""
    n, w = 16, 4
    regions = _regions(n)
    costs = np.array([100.0] + [1.0] * (n - 1))
    cost_fn = lambda r: costs[r.row0 // 10]
    ms_static = makespan(static_schedule(regions, w), regions, cost_fn)
    ms_lpt = makespan(lpt_schedule(regions, w, cost_fn), regions, cost_fn)
    assert ms_lpt <= ms_static
    ms_cw = makespan(
        cost_weighted_static_schedule(regions, w, cost_fn), regions, cost_fn
    )
    assert ms_cw <= ms_static  # contiguous but cost-aware


@given(st.integers(1, 40), st.integers(1, 8), st.integers(0, 10**6))
def test_work_stealing_partitions_all(n, w, seed):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10.0, size=n)
    sched = work_stealing_schedule(_regions(n), w, lambda r: costs[r.row0 // 10])
    flat = sorted(i for lst in sched for i in lst)
    assert flat == list(range(n))


@given(st.integers(1, 40), st.integers(1, 8), st.integers(0, 10**6))
def test_work_stealing_graham_bound(n, w, seed):
    """List scheduling obeys Graham's bound: makespan ≤ total/m + (1−1/m)·max,
    i.e. at most (2 − 1/m)× any lower bound — the guarantee that makes dynamic
    balancing safe for the paper's non-constant-cost pipelines (§IV.C)."""
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10.0, size=n)
    cost_fn = lambda r: costs[r.row0 // 10]
    regions = _regions(n)
    ms = makespan(work_stealing_schedule(regions, w, cost_fn), regions, cost_fn)
    assert ms <= costs.sum() / w + (1 - 1 / w) * costs.max() + 1e-9


@given(st.integers(2, 8))
def test_work_stealing_beats_static_on_skew(w):
    """One pathological region at the head of the queue: the static blocked
    split serializes it with its neighbors; stealing spreads the rest."""
    n = 4 * w
    regions = _regions(n)
    costs = np.array([50.0] + [1.0] * (n - 1))
    cost_fn = lambda r: costs[r.row0 // 10]
    ms_static = makespan(static_schedule(regions, w), regions, cost_fn)
    ms_ws = makespan(work_stealing_schedule(regions, w, cost_fn), regions, cost_fn)
    ms_lpt = makespan(lpt_schedule(regions, w, cost_fn), regions, cost_fn)
    assert ms_ws <= ms_static
    # LPT sorts by cost first, so it lower-bounds queue-order stealing here
    assert ms_lpt <= ms_ws + 1e-9


@given(st.integers(2, 30), st.integers(2, 6))
def test_cost_weighted_contiguous(n, w):
    sched = cost_weighted_static_schedule(_regions(n), w, lambda r: 1.0)
    flat = [i for lst in sched for i in lst]
    assert flat == list(range(n))
    for lst in sched:
        if lst:
            assert lst == list(range(lst[0], lst[0] + len(lst)))
