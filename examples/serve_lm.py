"""Serve a small LM with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py [--batch 8 --new-tokens 24]

Exercises the serving substrate used by the decode_32k / long_500k dry-run
cells (prefill step, per-token decode step, batched greedy/temperature
sampling) at CPU-friendly scale.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), n_layers=4)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.new_tokens)

    prompts = jnp.asarray(
        np.random.default_rng(0).integers(
            2, cfg.vocab_size, size=(args.batch, args.prompt_len)
        ),
        jnp.int32,
    )
    # warmup (compile prefill + decode)
    engine.generate(prompts, max_new_tokens=2)

    t0 = time.time()
    out = engine.generate(
        prompts, max_new_tokens=args.new_tokens,
        temperature=args.temperature, key=jax.random.PRNGKey(1),
    )
    dt = time.time() - t0
    n_new = args.batch * args.new_tokens
    print(f"arch={cfg.name} (reduced)  batch={args.batch}  "
          f"prompt={args.prompt_len}  new={args.new_tokens}")
    print(f"generated {n_new} tokens in {dt:.2f}s → {n_new/dt:.1f} tok/s")
    for i in range(min(3, args.batch)):
        print(f"  seq{i}: {np.asarray(out[i, args.prompt_len:]).tolist()}")


if __name__ == "__main__":
    main()
