"""End-to-end driver (the paper's production scenario): pansharpen a
synthetic Spot6 product pair and write the result with the strip-parallel
writer — the full P3 pipeline of Table 2.

    PYTHONPATH=src python examples/pansharpen_cluster.py [--xs-rows 512]

With one local device this runs the streamed executor (worker 0 of N); with
multiple devices (XLA_FLAGS=--xla_force_host_platform_device_count=8) it
runs the shard_map cluster executor — one pipeline replica per device, halo
exchange via ppermute, exactly the paper's §II.C.2.
"""
import argparse
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro import pipelines as PP
from repro.core import StripeSplitter
from repro.raster import as_source, make_spot6_pair


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--xs-rows", type=int, default=256)
    ap.add_argument("--xs-cols", type=int, default=256)
    ap.add_argument("--out", default=None,
                    help="output path (.rtif flat strip-parallel file, or "
                         ".rtic for the tiled pyramidal container)")
    args = ap.parse_args()

    out = args.out or str(Path(tempfile.mkdtemp()) / "pansharpened.rtif")
    xs, pan = make_spot6_pair(args.xs_rows, args.xs_cols)
    n_dev = len(jax.devices())

    print(f"product: XS {args.xs_rows}×{args.xs_cols}×4 + PAN "
          f"{args.xs_rows*4}×{args.xs_cols*4}")

    # sources and sinks are protocol objects: `sink=out` picks the writer
    # from the path (.rtic → TileWriter), and the executor choice doesn't
    # change the pixels — one plan registry serves both engines
    t0 = time.time()
    if n_dev > 1:
        print(f"cluster executor on {n_dev} devices (one pipeline replica each)")
        res, _ = PP.run_pipeline("P3", xs, pan, executor="spmd", sink=out)
    else:
        print("streaming executor (single worker)")
        res, _ = PP.run_pipeline(
            "P3", xs, pan, sink=out, splitter=StripeSplitter(n_splits=8)
        )
    dt = time.time() - t0

    mp = res.pixels_processed / 1e6
    print(f"processed {mp:.1f} Mpixels in {dt:.2f}s → {mp/dt:.1f} Mpix/s")
    got = as_source(out).read_region()  # container magic picks the reader
    assert np.isfinite(got).all()
    print(f"wrote {out} ({Path(out).stat().st_size/2**20:.1f} MiB) ✓")


if __name__ == "__main__":
    main()
