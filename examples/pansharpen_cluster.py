"""End-to-end driver (the paper's production scenario): pansharpen a
synthetic Spot6 product pair and write the result with the strip-parallel
writer — the full P3 pipeline of Table 2.

    PYTHONPATH=src python examples/pansharpen_cluster.py [--xs-rows 512]

With one local device this runs the streamed executor (worker 0 of N); with
multiple devices (XLA_FLAGS=--xla_force_host_platform_device_count=8) it
runs the shard_map cluster executor — one pipeline replica per device, halo
exchange via ppermute, exactly the paper's §II.C.2.
"""
import argparse
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro import pipelines as PP
from repro.core import ParallelExecutor, StreamingExecutor, StripeSplitter
from repro.raster import ParallelRasterWriter, make_spot6_pair
from repro.raster import io as rio


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--xs-rows", type=int, default=256)
    ap.add_argument("--xs-cols", type=int, default=256)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = args.out or str(Path(tempfile.mkdtemp()) / "pansharpened.rtif")
    xs, pan = make_spot6_pair(args.xs_rows, args.xs_cols)
    n_dev = len(jax.devices())

    p, mapper = PP.p3_pansharpening(
        xs, pan, mapper_factory=lambda: ParallelRasterWriter(out)
    )
    info = p.info(mapper)
    print(f"product: XS {args.xs_rows}×{args.xs_cols}×4 + PAN "
          f"{args.xs_rows*4}×{args.xs_cols*4} → out {info.rows}×{info.cols}×4")

    t0 = time.time()
    if n_dev > 1:
        print(f"cluster executor on {n_dev} devices (one pipeline replica each)")
        res = ParallelExecutor(p, mapper).run()
    else:
        print("streaming executor (single worker)")
        res = StreamingExecutor(p, mapper, StripeSplitter(n_splits=8)).run()
    dt = time.time() - t0

    mp = res.pixels_processed / 1e6
    print(f"processed {mp:.1f} Mpixels in {dt:.2f}s → {mp/dt:.1f} Mpix/s")
    got = rio.read_region(out)
    assert np.isfinite(got).all()
    print(f"wrote {out} ({Path(out).stat().st_size/2**20:.1f} MiB) ✓")


if __name__ == "__main__":
    main()
