"""Quickstart: build and run a geospatial pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's three ideas end to end: a process-object graph
(source → filter → persistent filter → mapper), a splitting strategy, and
bounded-memory streamed execution producing the same pixels as a
whole-image run.
"""
import numpy as np

from repro import pipelines as PP
from repro.core import AutoSplitter, Pipeline
from repro.filters import BandStatistics, ndvi
from repro.raster import MemoryMapper, SyntheticScene

# 1. wire the graph: 4-band synthetic Spot6-like scene → NDVI → stats → sink
p = Pipeline()
scene = p.add(SyntheticScene(rows=512, cols=512, bands=4, dtype=np.float32))
index = p.add(ndvi(red_band=0, nir_band=3), [scene])
stats = p.add(BandStatistics(bands=1), [index])
sink = p.add(MemoryMapper(), [stats])

# 2. choose the splitting strategy from a memory budget (paper §II.D):
#    stream the image through the pipeline in ~256 KiB regions
splitter = AutoSplitter(memory_budget_bytes=256 * 1024, n_workers=1)

# 3. execute through the unified runner (any executor, one plan registry);
#    a prebuilt (pipeline, mapper) pair goes in as-is — sources and sinks
#    are protocol objects, so a file path or ndarray would work here too
result, _ = PP.run_pipeline((p, sink), splitter=splitter)
ndvi_img = sink.result[..., 0]
s = result.persistent_results["BandStatistics"]

print(f"streamed {result.regions_processed} regions, "
      f"{result.pixels_processed:,} pixels")
print(f"NDVI range [{float(s['min'][0]):+.3f}, {float(s['max'][0]):+.3f}], "
      f"mean {float(s['mean'][0]):+.3f} ± {float(s['std'][0]):.3f}")

# 4. the paper's invariant: streaming == whole-image execution
whole = np.asarray(p.pull(sink, p.info(sink).full_region))[..., 0]
np.testing.assert_allclose(ndvi_img, whole, rtol=1e-5, atol=1e-5)
print("streamed output identical to whole-image execution ✓")
