"""Train a ~10M-parameter LM for a few hundred steps on synthetic bigram
data, with async checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The assigned architectures are pod-scale; this uses a width-reduced qwen
config so the full loop — sharded step, checkpointing, metrics — runs on
CPU in minutes.  Loss falls well below the unigram entropy because the data
has learnable bigram structure.)
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config, reduced
from repro.data import SyntheticTokens
from repro.train.loop import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(get_config("qwen1.5-0.5b")),
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=4096,
    )
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp()

    data = iter(SyntheticTokens(cfg.vocab_size, args.seq, args.batch, seed=0))
    trainer = Trainer(
        cfg,
        LoopConfig(steps=args.steps, ckpt_every=100, ckpt_dir=ckpt_dir,
                   lr=1e-3, log_every=20),
        data,
    )
    result = trainer.run()
    losses = [(m["step"], m["loss"]) for m in result["log"] if "loss" in m]
    for s, l in losses:
        print(f"step {s:4d}  loss {l:.4f}")
    first, last = losses[0][1], losses[-1][1]
    print(f"\nloss {first:.3f} → {last:.3f} over {result['final_step']} steps "
          f"({result['recoveries']} recoveries); checkpoints in {ckpt_dir}")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
